//! Serving-path benchmark (criterion-free): merged-vs-bypass forward
//! latency (including the crossover vs k ∈ {1, 2, 4, 8}), promotion
//! (merge) cost, the composed-vs-single mixture crossover at p ∈
//! {2, 4, 8} parts, and end-to-end scheduler throughput with continuous
//! micro-batching — for the decoder scoring path AND the encoder
//! classification path (the cls merged-vs-bypass crossover rides in the
//! same `BENCH_serve.json`). Drives the same code the `neuroada serve`
//! subcommand runs; numbers from here are the serving-perf baseline
//! recorded in PR descriptions and exported as JSON for the CI bench
//! artifact.

use super::{Bench, BenchResult};
use crate::config::{presets, ModelCfg};
use crate::coordinator::pool::Pool;
use crate::data::{cls_batch, eval_batch, example_stream, tasks, Split};
use crate::model::init::init_params;
use crate::peft::{selection::select_topk, DeltaStore};
use crate::runtime::ValueStore;
use crate::serve::scheduler::{host_cls_logits, host_logits};
use crate::serve::{
    AdapterRegistry, AdapterSpec, Backend, ClsRequest, MetricsReport, RegistryCfg, Request,
    ServeCfg, Server,
};
use crate::tensor::Tensor;
use crate::util::rng::Rng;
use anyhow::{anyhow, Result};

/// Forward latency at one sparsity level k: the merged path is k-invariant
/// (dense weights), the bypass pays O(d_out·k) extra per projection — the
/// crossover point tells the registry when merging starts paying off.
#[derive(Debug, Clone)]
pub struct KPoint {
    pub k: usize,
    pub merged_ms: f64,
    pub bypass_ms: f64,
}

/// Forward latency of a composed p-part mixture's bypass vs a single
/// adapter's bypass and the flat merged line: the composed union pays
/// O(Σ kᵢ) scatter slots per projection, so these cells record where
/// compose-on-resolve should hand a hot mixture to the merge machinery.
#[derive(Debug, Clone)]
pub struct ComposePoint {
    /// Component adapters in the mixture (each k=1).
    pub parts: usize,
    /// Single-adapter (k=1) bypass forward, ms.
    pub single_ms: f64,
    /// Composed-union bypass forward, ms.
    pub composed_ms: f64,
    /// Dense merged forward (k-invariant flat line), ms.
    pub merged_ms: f64,
}

/// One full serving-bench run.
pub struct ServeBenchReport {
    pub results: Vec<BenchResult>,
    /// End-to-end scheduler run with every adapter promoted (merged path).
    pub e2e_merged: MetricsReport,
    /// Same load with merging disabled (pure bypass path).
    pub e2e_bypass: MetricsReport,
    /// Merged-vs-bypass forward latency at k ∈ {1, 2, 4, 8} (ROADMAP:
    /// record the crossover point vs k).
    pub crossover: Vec<KPoint>,
    /// Composed-vs-single bypass forward at p ∈ {2, 4, 8} mixture parts
    /// (ISSUE-10: the composition crossover).
    pub compose: Vec<ComposePoint>,
    /// Encoder-classification serving bench (enc-micro), mirroring the
    /// decoder sections; `None` when the cls section failed (logged and
    /// skipped so an encoder problem cannot lose the decoder baseline).
    pub cls: Option<ClsBenchReport>,
    /// Wall-clock cost of request tracing: traced / untraced time for an
    /// identical e2e pass, min over interleaved rounds. The serving
    /// contract is <= 1.05×; the bench binary enforces it
    /// (`NEUROADA_TRACE_OVERHEAD_CAP` overrides the cap).
    pub trace_overhead: f64,
    /// Multi-size e2e sweep: one merged-path scheduler pass per size, the
    /// full [`MetricsReport`] (stage-latency breakdown included) kept per
    /// entry for `BENCH_serve.json`.
    pub sizes: Vec<(String, MetricsReport)>,
}

/// The encoder-classification half of the serving bench: cls forward
/// merged-vs-bypass (crossover vs k) plus end-to-end cls scheduler runs.
pub struct ClsBenchReport {
    pub size: String,
    pub results: Vec<BenchResult>,
    /// Merged-vs-bypass cls forward latency at k ∈ {1, 2, 4, 8}.
    pub crossover: Vec<KPoint>,
    /// End-to-end cls scheduler run with every adapter promoted.
    pub e2e_merged: MetricsReport,
    /// Same cls load with merging disabled (pure bypass path).
    pub e2e_bypass: MetricsReport,
}

impl ClsBenchReport {
    pub fn render(&self) -> String {
        let mut out = String::new();
        for r in &self.results {
            out.push_str(&r.render());
            out.push('\n');
        }
        for p in &self.crossover {
            out.push_str(&format!(
                "cls-crossover/k={:<22} merged {:>8.3} ms  bypass {:>8.3} ms  (bypass/merged {:.2}×)\n",
                p.k,
                p.merged_ms,
                p.bypass_ms,
                p.bypass_ms / p.merged_ms,
            ));
        }
        for (name, m) in [("merged", &self.e2e_merged), ("bypass", &self.e2e_bypass)] {
            let (p50, p95) = m
                .cls_latency
                .as_ref()
                .map(|s| (format!("{:.2}", s.p50 * 1e3), format!("{:.2}", s.p95 * 1e3)))
                .unwrap_or(("-".into(), "-".into()));
            out.push_str(&format!(
                "e2e-cls/{name:<30} p50 {p50:>8} ms  p95 {p95:>8} ms  {:.0} req/s  \
                 mean batch {:.2}\n",
                m.req_per_sec, m.cls_mean_batch,
            ));
        }
        out
    }

    /// Stable JSON blob (embedded under `"cls"` in `BENCH_serve.json`, or
    /// the whole document for `serve_bench -- --cls`).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut j = Json::obj();
        j.set("bench", "serve_bench_cls");
        j.set("size", self.size.as_str());
        let mut results = Vec::new();
        for r in &self.results {
            let mut o = Json::obj();
            o.set("name", r.name.as_str());
            o.set("mean_ms", r.summary.mean * 1e3);
            o.set("p50_ms", r.summary.p50 * 1e3);
            o.set("p95_ms", r.summary.p95 * 1e3);
            results.push(o);
        }
        j.set("results", Json::Arr(results));
        let mut cross = Vec::new();
        for p in &self.crossover {
            let mut o = Json::obj();
            o.set("k", p.k);
            o.set("merged_ms", p.merged_ms);
            o.set("bypass_ms", p.bypass_ms);
            cross.push(o);
        }
        j.set("crossover", Json::Arr(cross));
        for (name, m) in [("e2e_merged", &self.e2e_merged), ("e2e_bypass", &self.e2e_bypass)] {
            let mut o = Json::obj();
            o.set("req_per_sec", m.req_per_sec);
            o.set("cls_served", m.cls_served);
            o.set("cls_mean_batch", m.cls_mean_batch);
            if let Some(s) = &m.cls_latency {
                o.set("p50_ms", s.p50 * 1e3);
                o.set("p95_ms", s.p95 * 1e3);
            }
            j.set(name, o);
        }
        j
    }
}

impl ServeBenchReport {
    pub fn render(&self) -> String {
        let mut out = String::new();
        for r in &self.results {
            out.push_str(&r.render());
            out.push('\n');
        }
        for p in &self.crossover {
            out.push_str(&format!(
                "crossover/k={:<30} merged {:>8.3} ms  bypass {:>8.3} ms  (bypass/merged {:.2}×)\n",
                p.k,
                p.merged_ms,
                p.bypass_ms,
                p.bypass_ms / p.merged_ms,
            ));
        }
        for p in &self.compose {
            out.push_str(&format!(
                "compose/parts={:<28} single {:>8.3} ms  composed {:>8.3} ms  \
                 merged {:>8.3} ms  (composed/single {:.2}×)\n",
                p.parts,
                p.single_ms,
                p.composed_ms,
                p.merged_ms,
                p.composed_ms / p.single_ms,
            ));
        }
        for (name, m) in [("merged", &self.e2e_merged), ("bypass", &self.e2e_bypass)] {
            let (p50, p95) = m
                .latency
                .as_ref()
                .map(|s| (format!("{:.2}", s.p50 * 1e3), format!("{:.2}", s.p95 * 1e3)))
                .unwrap_or(("-".into(), "-".into()));
            out.push_str(&format!(
                "e2e/{name:<34} p50 {p50:>8} ms  p95 {p95:>8} ms  {:.0} req/s  \
                 mean batch {:.2}\n",
                m.req_per_sec, m.mean_batch,
            ));
        }
        out.push_str(&format!(
            "trace-overhead{:<24} traced/untraced e2e {:.3}x (min of interleaved rounds)\n",
            "", self.trace_overhead,
        ));
        for (size, m) in &self.sizes {
            let stages: Vec<String> = crate::serve::metrics::StageLat::ALL
                .iter()
                .filter_map(|s| m.stage(*s).map(|x| format!("{} {:.2}ms", s.name(), x.p50 * 1e3)))
                .collect();
            out.push_str(&format!(
                "e2e-size/{size:<29} {:.0} req/s  p50 stages: {}\n",
                m.req_per_sec,
                stages.join("  "),
            ));
        }
        if let Some(cls) = &self.cls {
            out.push_str(&cls.render());
        }
        out
    }

    /// Stable JSON blob for the CI bench artifact.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut j = Json::obj();
        j.set("bench", "serve_bench");
        let mut results = Vec::new();
        for r in &self.results {
            let mut o = Json::obj();
            o.set("name", r.name.as_str());
            o.set("mean_ms", r.summary.mean * 1e3);
            o.set("p50_ms", r.summary.p50 * 1e3);
            o.set("p95_ms", r.summary.p95 * 1e3);
            results.push(o);
        }
        j.set("results", Json::Arr(results));
        let mut cross = Vec::new();
        for p in &self.crossover {
            let mut o = Json::obj();
            o.set("k", p.k);
            o.set("merged_ms", p.merged_ms);
            o.set("bypass_ms", p.bypass_ms);
            cross.push(o);
        }
        j.set("crossover", Json::Arr(cross));
        let mut comp = Vec::new();
        for p in &self.compose {
            let mut o = Json::obj();
            o.set("parts", p.parts);
            o.set("single_ms", p.single_ms);
            o.set("composed_ms", p.composed_ms);
            o.set("merged_ms", p.merged_ms);
            comp.push(o);
        }
        j.set("compose", Json::Arr(comp));
        for (name, m) in [("e2e_merged", &self.e2e_merged), ("e2e_bypass", &self.e2e_bypass)] {
            let mut o = Json::obj();
            o.set("req_per_sec", m.req_per_sec);
            o.set("mean_batch", m.mean_batch);
            if let Some(s) = &m.latency {
                o.set("p50_ms", s.p50 * 1e3);
                o.set("p95_ms", s.p95 * 1e3);
            }
            j.set(name, o);
        }
        j.set("trace_overhead", self.trace_overhead);
        let mut sizes = Vec::new();
        for (size, m) in &self.sizes {
            // the full metrics snapshot — its "stages" object is the
            // per-size stage-latency breakdown the ROADMAP sweep asks for
            let mut o = m.to_json();
            o.set("size", size.as_str());
            sizes.push(o);
        }
        j.set("sizes", Json::Arr(sizes));
        if let Some(cls) = &self.cls {
            j.set("cls", cls.to_json());
        }
        j
    }
}

/// Seeded fill for an all-zero encoder classifier head (`init_params`
/// zeroes it; training is what normally fills it). Serving demos, benches
/// and tests call this so synthetic cls traffic is non-degenerate —
/// with a zero head every class logit is exactly 0 and every prediction
/// is class 0. A trained head (any nonzero value) or a decoder config is
/// left untouched. Returns whether the head was randomized.
pub fn randomize_zero_head(cfg: &ModelCfg, store: &mut ValueStore, seed: u64) -> Result<bool> {
    if cfg.n_classes == 0 {
        return Ok(false);
    }
    if store.get("params.head")?.as_f32()?.iter().any(|&v| v != 0.0) {
        return Ok(false);
    }
    let mut head = vec![0.0f32; cfg.n_classes * cfg.d_model];
    Rng::new(seed).fill_normal(&mut head, 0.1);
    store.insert_f32("params.head", &[cfg.n_classes, cfg.d_model], head);
    Ok(true)
}

/// Synthesize a full-coverage adapter (one k-sparse delta per projection),
/// deterministically from `seed`.
pub fn synth_adapter(
    cfg: &ModelCfg,
    backbone: &ValueStore,
    k: usize,
    seed: u64,
) -> Result<Vec<(String, DeltaStore)>> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    for (name, d_out, d_in) in cfg.proj_shapes() {
        let w = backbone.get(&format!("params.{name}"))?.as_f32()?.to_vec();
        let wt = Tensor::from_vec(&[d_out, d_in], w);
        let sel = select_topk(&wt, k);
        let vals: Vec<f32> = (0..d_out * k).map(|_| rng.normal() * 0.05).collect();
        out.push((name, DeltaStore::from_f32(sel, &vals)));
    }
    Ok(out)
}

/// Synthesize `n` distinct adapters, scattered across the worker pool.
pub fn synth_adapters(
    cfg: &ModelCfg,
    backbone: &ValueStore,
    n: usize,
    k: usize,
    seed: u64,
) -> Result<Vec<(String, Vec<(String, DeltaStore)>)>> {
    let pool = Pool::new(Pool::default_size());
    let jobs: Vec<Box<dyn FnOnce() -> Result<(String, Vec<(String, DeltaStore)>)> + Send>> = (0
        ..n)
        .map(|i| {
            let cfg = cfg.clone();
            let backbone = backbone.clone();
            let job: Box<dyn FnOnce() -> Result<(String, Vec<(String, DeltaStore)>)> + Send> =
                Box::new(move || {
                    let deltas = synth_adapter(&cfg, &backbone, k, seed ^ ((i as u64 + 1) << 8))?;
                    Ok((format!("adapter-{i}"), deltas))
                });
            job
        })
        .collect();
    pool.scatter(jobs).into_iter().collect()
}

fn gen_requests(cfg: &ModelCfg, adapters: &[String], n: usize, seed: u64) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            let plen = 6 + rng.below(cfg.seq / 2);
            Request {
                adapter: adapters[i % adapters.len()].clone(),
                prompt: (0..plen).map(|_| 4 + rng.below(cfg.vocab - 4) as i32).collect(),
                options: vec![4, 5],
            }
        })
        .collect()
}

/// Task-shaped cls traffic (sentence pairs from the GLUE-like generators),
/// round-robin across adapters.
fn gen_cls_requests(cfg: &ModelCfg, adapters: &[String], n: usize, seed: u64) -> Vec<ClsRequest> {
    let task = tasks::by_name("glue-mnli").expect("registry task");
    example_stream(&task, Split::Test, seed, cfg.vocab, cfg.seq, n)
        .into_iter()
        .enumerate()
        .map(|(i, ex)| ClsRequest {
            adapter: adapters[i % adapters.len()].clone(),
            tokens: ex.prompt,
        })
        .collect()
}

fn e2e_cls(
    cfg: &ModelCfg,
    backbone: &ValueStore,
    adapters: &[(String, Vec<(String, DeltaStore)>)],
    rcfg: RegistryCfg,
    requests: Vec<ClsRequest>,
    clients: usize,
) -> Result<MetricsReport> {
    let reg = AdapterRegistry::new(cfg.clone(), backbone.clone(), rcfg);
    for (name, deltas) in adapters {
        reg.register(name, deltas.clone())?;
    }
    let scfg = ServeCfg {
        max_batch: cfg.batch,
        max_queue: requests.len().max(1),
        max_delay: std::time::Duration::from_millis(5),
        workers: Pool::default_size(),
        ..ServeCfg::default()
    };
    let srv = Server::start(reg, scfg, Backend::Host)?;
    let (_served, rejected) = srv.drive_cls_clients(requests, clients);
    anyhow::ensure!(rejected == 0, "e2e cls bench rejected {rejected} requests");
    Ok(srv.shutdown())
}

/// Run the encoder-classification serving bench (the cls mirror of
/// [`run`]'s forward/crossover/e2e sections). Standalone entry for
/// `cargo bench --bench serve_bench -- --cls`; also embedded in the full
/// report so the cls crossover lands in `BENCH_serve.json`.
pub fn run_cls(
    size: &str,
    n_adapters: usize,
    n_requests: usize,
    quick: bool,
) -> Result<ClsBenchReport> {
    let cfg = presets::model(size).ok_or_else(|| anyhow!("unknown size {size:?}"))?;
    anyhow::ensure!(cfg.n_classes > 0, "cls bench needs an encoder size");
    let b = if quick { Bench::quick() } else { Bench::default() };
    let mut rng = Rng::new(8);
    let mut backbone = init_params(&cfg, &mut rng);
    randomize_zero_head(&cfg, &mut backbone, 0x4EAD)?;
    let adapters = synth_adapters(&cfg, &backbone, n_adapters.max(2), 1, 88)?;
    let names: Vec<String> = adapters.iter().map(|(n, _)| n.clone()).collect();

    // --- single-batch cls forward: merged vs bypass ----------------------
    let reg = AdapterRegistry::new(
        cfg.clone(),
        backbone.clone(),
        RegistryCfg { merged_capacity: 1, promote_after: 1, ..RegistryCfg::default() },
    );
    for (name, deltas) in &adapters {
        reg.register(name, deltas.clone())?;
    }
    let n = cfg.batch.min(8);
    let task = tasks::by_name("glue-sst2").expect("registry task");
    let examples = example_stream(&task, Split::Test, 13, cfg.vocab, cfg.seq, n);
    let cb = cls_batch(&examples, cfg.seq);
    let mut results = Vec::new();
    let merged = reg.merge_now(&names[0])?;
    let r_merged = b.run(&format!("cls/merged {size} b={n}"), || {
        std::hint::black_box(
            host_cls_logits(&cfg, &merged, &cb.tokens, &cb.pad_mask, n).unwrap().numel(),
        );
    });
    // like the decoder section: the merged cls forward is k-invariant
    let merged_ms = r_merged.summary.mean * 1e3;
    results.push(r_merged);
    let bypass = reg.bypass(&names[0])?;
    results.push(b.run(&format!("cls/bypass {size} b={n}"), || {
        std::hint::black_box(
            host_cls_logits(&cfg, &bypass, &cb.tokens, &cb.pad_mask, n).unwrap().numel(),
        );
    }));

    // --- merged-vs-bypass cls crossover vs k -----------------------------
    let mut crossover = Vec::new();
    for k in [1usize, 2, 4, 8] {
        let name = format!("cls-crossover-k{k}");
        reg.register(&name, synth_adapter(&cfg, &backbone, k, 0xC00 + k as u64)?)?;
        let view = reg.bypass(&name)?;
        let r = b.run(&format!("cls/bypass {size} b={n} k={k}"), || {
            std::hint::black_box(
                host_cls_logits(&cfg, &view, &cb.tokens, &cb.pad_mask, n).unwrap().numel(),
            );
        });
        crossover.push(KPoint { k, merged_ms, bypass_ms: r.summary.mean * 1e3 });
        results.push(r);
    }

    // --- end-to-end cls scheduler: merged vs bypass ----------------------
    let n_req = if quick { n_requests.min(32) } else { n_requests };
    let clients = 4;
    let requests = gen_cls_requests(&cfg, &names, n_req, 17);
    let e2e_merged = e2e_cls(
        &cfg,
        &backbone,
        &adapters,
        RegistryCfg { merged_capacity: adapters.len(), promote_after: 1, ..RegistryCfg::default() },
        requests.clone(),
        clients,
    )?;
    let e2e_bypass = e2e_cls(
        &cfg,
        &backbone,
        &adapters,
        RegistryCfg { merged_capacity: 0, promote_after: 1, ..RegistryCfg::default() },
        requests,
        clients,
    )?;
    Ok(ClsBenchReport { size: size.to_string(), results, crossover, e2e_merged, e2e_bypass })
}

fn e2e(
    cfg: &ModelCfg,
    backbone: &ValueStore,
    adapters: &[(String, Vec<(String, DeltaStore)>)],
    rcfg: RegistryCfg,
    requests: Vec<Request>,
    clients: usize,
    trace: bool,
) -> Result<MetricsReport> {
    let reg = AdapterRegistry::new(cfg.clone(), backbone.clone(), rcfg);
    for (name, deltas) in adapters {
        reg.register(name, deltas.clone())?;
    }
    let scfg = ServeCfg {
        max_batch: cfg.batch,
        max_queue: requests.len().max(1),
        max_delay: std::time::Duration::from_millis(5),
        workers: Pool::default_size(),
        trace,
        ..ServeCfg::default()
    };
    let srv = Server::start(reg, scfg, Backend::Host)?;
    let (_served, rejected) = srv.drive_clients(requests, clients);
    anyhow::ensure!(rejected == 0, "e2e bench rejected {rejected} requests");
    Ok(srv.shutdown())
}

/// One self-contained e2e scheduler pass at `size` (own backbone +
/// synthetic adapters), for the multi-size sweep: the returned
/// [`MetricsReport`] carries the per-stage latency breakdown that lands
/// in `BENCH_serve.json` under `"sizes"`.
fn e2e_for_size(size: &str, n_requests: usize, clients: usize) -> Result<MetricsReport> {
    let cfg = presets::model(size).ok_or_else(|| anyhow!("unknown size {size:?}"))?;
    anyhow::ensure!(cfg.n_classes == 0, "size sweep needs decoder sizes");
    let mut rng = Rng::new(7);
    let backbone = init_params(&cfg, &mut rng);
    let adapters = synth_adapters(&cfg, &backbone, 2, 1, 77)?;
    let names: Vec<String> = adapters.iter().map(|(n, _)| n.clone()).collect();
    let requests = gen_requests(&cfg, &names, n_requests, 29);
    e2e(
        &cfg,
        &backbone,
        &adapters,
        RegistryCfg { merged_capacity: adapters.len(), promote_after: 1, ..RegistryCfg::default() },
        requests,
        clients,
        false,
    )
}

/// Run the full serving bench.
pub fn run(size: &str, n_adapters: usize, n_requests: usize, quick: bool) -> Result<ServeBenchReport> {
    let cfg = presets::model(size).ok_or_else(|| anyhow!("unknown size {size:?}"))?;
    anyhow::ensure!(cfg.n_classes == 0, "serve bench needs a decoder size");
    let b = if quick { Bench::quick() } else { Bench::default() };
    let mut rng = Rng::new(7);
    let backbone = init_params(&cfg, &mut rng);
    let adapters = synth_adapters(&cfg, &backbone, n_adapters.max(2), 1, 77)?;
    let names: Vec<String> = adapters.iter().map(|(n, _)| n.clone()).collect();

    // --- single-batch forward: merged vs bypass --------------------------
    let reg = AdapterRegistry::new(
        cfg.clone(),
        backbone.clone(),
        RegistryCfg { merged_capacity: 1, promote_after: 1, ..RegistryCfg::default() },
    );
    for (name, deltas) in &adapters {
        reg.register(name, deltas.clone())?;
    }
    let reqs = gen_requests(&cfg, &names[..1], cfg.batch, 5);
    let examples: Vec<crate::data::Example> = reqs
        .iter()
        .map(|r| crate::data::Example {
            prompt: r.prompt.clone(),
            answer_tok: 0,
            label: 0,
            options: r.options.clone(),
            score: 0.0,
        })
        .collect();
    let eb = eval_batch(&examples, cfg.seq);
    let n = reqs.len();
    let mut results = Vec::new();

    let merged = reg.merge_now(&names[0])?;
    let r_merged = b.run(&format!("forward/merged {size} b={n}"), || {
        std::hint::black_box(
            host_logits(&cfg, &merged, &eb.tokens, &eb.pad_mask, &eb.last_pos, n).unwrap().numel(),
        );
    });
    // the merged forward is k-invariant (dense weights): one measurement
    // is the flat line every bypass-at-k point is compared against
    let merged_ms = r_merged.summary.mean * 1e3;
    results.push(r_merged);
    let bypass = reg.bypass(&names[0])?;
    results.push(b.run(&format!("forward/bypass {size} b={n}"), || {
        std::hint::black_box(
            host_logits(&cfg, &bypass, &eb.tokens, &eb.pad_mask, &eb.last_pos, n).unwrap().numel(),
        );
    }));

    // --- merged-vs-bypass crossover vs k (ROADMAP item) ------------------
    let mut crossover = Vec::new();
    for k in [1usize, 2, 4, 8] {
        let name = format!("crossover-k{k}");
        reg.register(&name, synth_adapter(&cfg, &backbone, k, 0x900 + k as u64)?)?;
        let view = reg.bypass(&name)?;
        let r = b.run(&format!("forward/bypass {size} b={n} k={k}"), || {
            std::hint::black_box(
                host_logits(&cfg, &view, &eb.tokens, &eb.pad_mask, &eb.last_pos, n)
                    .unwrap()
                    .numel(),
            );
        });
        crossover.push(KPoint { k, merged_ms, bypass_ms: r.summary.mean * 1e3 });
        results.push(r);
    }

    // --- composed-vs-single crossover vs mixture parts (ISSUE-10) --------
    // A p-part mixture of k=1 adapters composes into one union store whose
    // bypass pays up to p scatter slots per neuron — these cells record
    // where the composed bypass crosses the single-adapter bypass (~p×)
    // and the flat merged line, i.e. when a hot mixture should promote.
    // single_ms is the k=1 crossover cell's bypass — the same measurement.
    let single_ms = crossover[0].bypass_ms;
    let mut compose = Vec::new();
    for i in 0..8usize {
        let name = format!("compose-part-{i}");
        reg.register(&name, synth_adapter(&cfg, &backbone, 1, 0xA00 + i as u64)?)?;
    }
    for parts in [2usize, 4, 8] {
        let spec_str: String =
            (0..parts).map(|i| format!("compose-part-{i}")).collect::<Vec<_>>().join("+");
        let spec = AdapterSpec::parse(&spec_str).map_err(|e| anyhow!(e))?;
        // no-promote resolve: compose-on-resolve runs, but the view stays
        // on the bypass so the cell measures the union scatter cost
        let view = reg
            .resolve_spec_no_promote(&spec)
            .ok_or_else(|| anyhow!("compose failed for {spec_str}"))?;
        let r = b.run(&format!("forward/composed {size} b={n} parts={parts}"), || {
            std::hint::black_box(
                host_logits(&cfg, &view, &eb.tokens, &eb.pad_mask, &eb.last_pos, n)
                    .unwrap()
                    .numel(),
            );
        });
        compose.push(ComposePoint {
            parts,
            single_ms,
            composed_ms: r.summary.mean * 1e3,
            merged_ms,
        });
        results.push(r);
    }

    // --- promotion (merge) cost ------------------------------------------
    results.push(b.run(&format!("registry/merge {size}"), || {
        reg.demote(&names[0]);
        std::hint::black_box(reg.merge_now(&names[0]).is_ok());
    }));

    // --- end-to-end scheduler: merged vs bypass --------------------------
    let n_req = if quick { n_requests.min(64) } else { n_requests };
    let clients = 4;
    let requests = gen_requests(&cfg, &names, n_req, 11);
    let e2e_merged = e2e(
        &cfg,
        &backbone,
        &adapters,
        RegistryCfg { merged_capacity: adapters.len(), promote_after: 1, ..RegistryCfg::default() },
        requests.clone(),
        clients,
        false,
    )?;
    let e2e_bypass = e2e(
        &cfg,
        &backbone,
        &adapters,
        RegistryCfg { merged_capacity: 0, promote_after: 1, ..RegistryCfg::default() },
        requests,
        clients,
        false,
    )?;

    // --- tracing overhead: traced vs untraced e2e, interleaved -----------
    // Min-of-rounds wall clock on identical load; interleaving (off, on,
    // off, on, ...) keeps cache/thermal drift from loading one side. The
    // ratio is the cost of ServeCfg::trace and is gated by the bench
    // binary (NEUROADA_TRACE_OVERHEAD_CAP, default 1.05).
    let rounds = if quick { 2 } else { 3 };
    let overhead_reqs = gen_requests(&cfg, &names, n_req, 23);
    let mut t_off = f64::INFINITY;
    let mut t_on = f64::INFINITY;
    for _ in 0..rounds {
        for (trace, best) in [(false, &mut t_off), (true, &mut t_on)] {
            let t0 = std::time::Instant::now();
            e2e(
                &cfg,
                &backbone,
                &adapters,
                RegistryCfg { merged_capacity: adapters.len(), promote_after: 1, ..RegistryCfg::default() },
                overhead_reqs.clone(),
                clients,
                trace,
            )?;
            let dt = t0.elapsed().as_secs_f64();
            *best = best.min(dt);
        }
    }
    let trace_overhead = t_on / t_off;

    // --- multi-size e2e sweep (ROADMAP): stage breakdown per size --------
    // Each size gets its own backbone/adapters and a merged-path scheduler
    // pass; the full MetricsReport (stage latency fields included) embeds
    // in BENCH_serve.json under "sizes". Quick mode sweeps only the bench's
    // own size so tests stay fast.
    let sweep_sizes: Vec<&str> = if quick { vec![size] } else { vec!["micro", "small"] };
    let mut sizes = Vec::new();
    for s in sweep_sizes {
        let m = e2e_for_size(s, if quick { n_req.min(16) } else { n_requests.min(64) }, clients)?;
        sizes.push((s.to_string(), m));
    }

    // encoder-classification mirror (ROADMAP: GLUE-suite serving): the cls
    // merged-vs-bypass crossover rides in the same BENCH_serve.json. A cls
    // failure degrades to `cls: null` rather than losing the decoder
    // baseline (the standalone `serve_bench -- --cls` surfaces it loudly).
    let cls = match run_cls("enc-micro", 2, n_requests.min(32), quick) {
        Ok(r) => Some(r),
        Err(e) => {
            eprintln!("serve_bench: cls section skipped: {e:#}");
            None
        }
    };
    Ok(ServeBenchReport {
        results,
        e2e_merged,
        e2e_bypass,
        crossover,
        compose,
        cls,
        trace_overhead,
        sizes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_bench_runs() {
        let r = run("nano", 2, 16, true).unwrap();
        // merged + bypass + 4 crossover + 3 composed + merge cost
        assert_eq!(r.results.len(), 10);
        assert_eq!(r.crossover.len(), 4);
        for p in &r.crossover {
            assert!(p.merged_ms > 0.0 && p.bypass_ms > 0.0);
        }
        // the composition crossover cells: p ∈ {2, 4, 8} parts, every
        // latency positive and the single baseline shared with k=1
        assert_eq!(r.compose.iter().map(|p| p.parts).collect::<Vec<_>>(), vec![2, 4, 8]);
        for p in &r.compose {
            assert!(p.single_ms > 0.0 && p.composed_ms > 0.0 && p.merged_ms > 0.0);
            assert_eq!(p.single_ms, r.crossover[0].bypass_ms);
        }
        assert!(r.render().contains("compose/parts="));
        let j = r.to_json();
        assert_eq!(j.at(&["crossover"]).and_then(|c| c.as_arr()).map(|a| a.len()), Some(4));
        assert_eq!(j.at(&["compose"]).and_then(|c| c.as_arr()).map(|a| a.len()), Some(3));
        assert!(j.at(&["compose"]).and_then(|c| c.as_arr()).unwrap()[0]
            .at(&["composed_ms"])
            .and_then(|v| v.as_f64())
            .is_some());
        assert!(j.at(&["e2e_merged", "req_per_sec"]).and_then(|v| v.as_f64()).is_some());
        // the embedded cls section mirrors the decoder one
        let cls = r.cls.as_ref().expect("cls bench embedded");
        assert_eq!(cls.crossover.len(), 4);
        for p in &cls.crossover {
            assert!(p.merged_ms > 0.0 && p.bypass_ms > 0.0);
        }
        assert_eq!(cls.e2e_merged.cls_served, 16);
        assert_eq!(cls.e2e_bypass.cls_served, 16);
        assert_eq!(
            j.at(&["cls", "crossover"]).and_then(|c| c.as_arr()).map(|a| a.len()),
            Some(4)
        );
        assert!(j.at(&["cls", "e2e_merged", "req_per_sec"]).and_then(|v| v.as_f64()).is_some());
        assert!(r.render().contains("e2e-cls/merged"));
        assert_eq!(r.e2e_merged.served, 16);
        assert_eq!(r.e2e_bypass.served, 16);
        // path accounting: promotion happened in the merged run (a batch
        // racing an in-flight merge may still ride the bypass, so merged
        // hits are the deterministic signal); capacity 0 never merges
        for c in r.e2e_merged.adapters.values() {
            assert!(c.merged_hits > 0, "expected promotion: {c:?}");
        }
        for c in r.e2e_bypass.adapters.values() {
            assert_eq!(c.merged_hits, 0);
        }
        assert!(r.render().contains("e2e/merged"));
        // the tracing-overhead cell measured something sane (quick runs on
        // loaded CI boxes are noisy; the <=1.05 contract is gated by the
        // bench binary on the full run, not here)
        assert!(r.trace_overhead.is_finite() && r.trace_overhead > 0.0);
        assert!(j.at(&["trace_overhead"]).and_then(|v| v.as_f64()).is_some());
        // the multi-size sweep (quick: just this size) embeds the full
        // metrics snapshot, stage breakdown included
        assert_eq!(r.sizes.len(), 1);
        assert_eq!(r.sizes[0].0, "nano");
        let sz = j.at(&["sizes"]).and_then(|s| s.as_arr()).expect("sizes array");
        assert_eq!(sz.len(), 1);
        assert_eq!(sz[0].at(&["size"]).and_then(|v| v.as_str()), Some("nano"));
        assert!(sz[0].at(&["stages", "queue_wait", "p50"]).and_then(|v| v.as_f64()).is_some());
        // the embedded metrics snapshots carry the backbone residency pair
        assert_eq!(r.e2e_merged.backbone_dtype, "f32");
        assert!(r.e2e_merged.backbone_bytes > 0);
        assert_eq!(sz[0].at(&["backbone", "dtype"]).and_then(|v| v.as_str()), Some("f32"));
        assert!(sz[0].at(&["backbone", "bytes"]).and_then(|v| v.as_usize()).unwrap() > 0);
        assert!(r.render().contains("e2e-size/nano"));
        assert!(r.render().contains("trace-overhead"));
    }
}
