//! Forward-path benchmark (criterion-free): planned zero-copy resolution
//! vs the legacy per-call weight-copying forward, × 1 vs N threads, ×
//! nano/micro, × merged/bypass — the ISSUE-3 acceptance matrix.
//!
//! The **legacy** baseline in [`legacy`] is a faithful transcription of the
//! pre-plan `RefModel`: `format!`-keyed store lookups inside per-row loops,
//! `to_vec()` weight copies per projection per forward (4·d²·n_layers +
//! 2·d·d_ff·n_layers floats per call), single-threaded matmuls. It exists
//! for two reasons: as the bench's comparison point, and as the parity
//! oracle — the planned forward must reproduce its logits to ≤ 1e-6
//! (`rust/tests/planned_forward.rs`; the batch kernels are in fact
//! bit-identical by construction). A parity gate runs here before any
//! timing, because a speedup over diverging outputs would be meaningless.
//!
//! The report serializes to `BENCH_forward.json` (see `docs/performance.md`
//! for the schema); CI runs the bench binary quick-mode at
//! `NEUROADA_THREADS=1` and `=4` in the decode-smoke step and uploads the
//! blobs with the other `BENCH_*` artifacts. The binary (not this module's
//! tests, which must stay load-insensitive) asserts the two CI floors:
//! micro plan multi-thread ≥ 1.5× plan single-thread, and micro plan
//! multi-thread ≥ 2× legacy single-thread, both at batch 8.

use super::{Bench, BenchResult};
use crate::config::presets;
use crate::model::init::init_params;
use crate::model::{DeltaOverlay, PlannedModel};
use crate::tensor::ops::Kernel;
use crate::tensor::pool::KernelPool;
use crate::tensor::quant::{BackboneDtype, MatRef, QuantMat, QuantStore};
use crate::tensor::{ops, Tensor};
use crate::util::json::Json;
use crate::util::rng::Rng;
use anyhow::{anyhow, Result};

/// The pre-refactor forward, kept verbatim as baseline + parity oracle.
pub mod legacy {
    use crate::config::ModelCfg;
    use crate::model::decode::DecodeState;
    use crate::model::DeltaOverlay;
    use crate::runtime::ValueStore;
    use crate::tensor::{ops, Tensor};
    use anyhow::Result;

    /// The original `RefModel`: per-call name resolution and weight copies.
    pub struct LegacyModel<'a> {
        pub cfg: &'a ModelCfg,
        pub params: &'a ValueStore,
        pub overlay: Option<&'a DeltaOverlay<'a>>,
    }

    /// The serial `A·Bᵀ` the pre-redesign `ops::matmul_nt` provided, now
    /// routed through the unified dispatch (bit-identical: same dot kernel
    /// per element), kept local so the oracle's shape survives API churn.
    fn matmul_nt(a: &Tensor, b: &Tensor) -> Tensor {
        use crate::tensor::pool::KernelPool;
        use crate::tensor::quant::MatRef;
        let (m, k) = (a.shape[0], a.shape[1]);
        let n = b.shape[0];
        assert_eq!(k, b.shape[1]);
        let mut c = Tensor::zeros(&[m, n]);
        ops::gemm_nt(&a.data, m, k, MatRef::F32(&b.data), n, &mut c.data, &KernelPool::serial());
        c
    }

    impl<'a> LegacyModel<'a> {
        fn p(&self, name: &str) -> Result<&[f32]> {
            self.params.get(&format!("params.{name}"))?.as_f32()
        }

        /// The copy the plan removed: a dense `Tensor` clone of the weight.
        fn p2(&self, name: &str, d_out: usize, d_in: usize) -> Result<Tensor> {
            Ok(Tensor::from_vec(&[d_out, d_in], self.p(name)?.to_vec()))
        }

        fn proj(&self, h: &Tensor, name: &str, w: &Tensor) -> Tensor {
            let mut y = matmul_nt(h, w);
            if let Some(view) = self.overlay.and_then(|o| o.get(name)) {
                view.accum_matmul_nt(h, &mut y);
            }
            y
        }

        fn hidden(&self, tokens: &[i32], pad_mask: &[f32], b: usize) -> Result<Tensor> {
            let cfg = self.cfg;
            let (t, d) = (cfg.seq, cfg.d_model);
            assert_eq!(tokens.len(), b * t);
            let embed = self.p("embed")?;
            let pos = ops::positional(t, d);
            let mut x = Tensor::zeros(&[b * t, d]);
            for i in 0..b * t {
                let tok = tokens[i] as usize;
                let row = &embed[tok * d..(tok + 1) * d];
                let pr = pos.row(i % t);
                let xr = x.row_mut(i);
                for j in 0..d {
                    xr[j] = row[j] + pr[j];
                }
            }
            let mut h = Tensor::zeros(&[b * t, d]);
            for l in 0..cfg.n_layers {
                for i in 0..b * t {
                    // the per-row re-resolution the plan eliminated
                    ops::rmsnorm(x.row(i), self.p(&format!("l{l}.ln1"))?, h.row_mut(i));
                }
                let wq = self.p2(&format!("l{l}.wq"), d, d)?;
                let wk = self.p2(&format!("l{l}.wk"), d, d)?;
                let wv = self.p2(&format!("l{l}.wv"), d, d)?;
                let wo = self.p2(&format!("l{l}.wo"), d, d)?;
                let q = self.proj(&h, &format!("l{l}.wq"), &wq);
                let k = self.proj(&h, &format!("l{l}.wk"), &wk);
                let v = self.proj(&h, &format!("l{l}.wv"), &wv);
                let att = self.attention(&q, &k, &v, pad_mask, b);
                let o = self.proj(&att, &format!("l{l}.wo"), &wo);
                x.add_assign(&o);
                for i in 0..b * t {
                    ops::rmsnorm(x.row(i), self.p(&format!("l{l}.ln2"))?, h.row_mut(i));
                }
                let w1 = self.p2(&format!("l{l}.w1"), cfg.d_ff, d)?;
                let w2 = self.p2(&format!("l{l}.w2"), d, cfg.d_ff)?;
                let mut m = self.proj(&h, &format!("l{l}.w1"), &w1);
                for vv in m.data.iter_mut() {
                    *vv = ops::silu(*vv);
                }
                let mm = self.proj(&m, &format!("l{l}.w2"), &w2);
                x.add_assign(&mm);
            }
            let mut out = Tensor::zeros(&[b * t, d]);
            for i in 0..b * t {
                ops::rmsnorm(x.row(i), self.p("ln_f")?, out.row_mut(i));
            }
            Ok(out)
        }

        fn attention(&self, q: &Tensor, k: &Tensor, v: &Tensor, pad_mask: &[f32], b: usize) -> Tensor {
            let cfg = self.cfg;
            let (t, d) = (cfg.seq, cfg.d_model);
            let (nh, hd) = (cfg.n_heads, cfg.d_model / cfg.n_heads);
            let scale = 1.0 / (hd as f32).sqrt();
            let mut out = Tensor::zeros(&[b * t, d]);
            let mut scores = Tensor::zeros(&[t, t]);
            for bi in 0..b {
                for h in 0..nh {
                    for qi in 0..t {
                        let qrow = &q.row(bi * t + qi)[h * hd..(h + 1) * hd];
                        for ki in 0..t {
                            let masked =
                                (cfg.causal && ki > qi) || pad_mask[bi * t + ki] == 0.0;
                            let s = if masked {
                                -1e9
                            } else {
                                let krow = &k.row(bi * t + ki)[h * hd..(h + 1) * hd];
                                qrow.iter().zip(krow).map(|(a, b)| a * b).sum::<f32>() * scale
                            };
                            scores.set2(qi, ki, s);
                        }
                    }
                    ops::softmax_rows(&mut scores);
                    for qi in 0..t {
                        let orow = &mut out.row_mut(bi * t + qi)[h * hd..(h + 1) * hd];
                        for ki in 0..t {
                            let w = scores.at2(qi, ki);
                            if w == 0.0 {
                                continue;
                            }
                            let vrow = &v.row(bi * t + ki)[h * hd..(h + 1) * hd];
                            for j in 0..hd {
                                orow[j] += w * vrow[j];
                            }
                        }
                    }
                }
            }
            out
        }

        pub fn lm_logits_at(
            &self,
            tokens: &[i32],
            pad_mask: &[f32],
            last_pos: &[i32],
            b: usize,
        ) -> Result<Tensor> {
            let cfg = self.cfg;
            let h = self.hidden(tokens, pad_mask, b)?;
            let embed =
                Tensor::from_vec(&[cfg.vocab, cfg.d_model], self.p("embed")?.to_vec());
            let mut sel = Tensor::zeros(&[b, cfg.d_model]);
            for bi in 0..b {
                let pos = last_pos[bi] as usize;
                sel.row_mut(bi).copy_from_slice(h.row(bi * cfg.seq + pos));
            }
            Ok(matmul_nt(&sel, &embed))
        }

        fn proj_step(&self, h: &[f32], name: &str, d_out: usize, d_in: usize) -> Result<Vec<f32>> {
            let w = self.p(name)?;
            let mut y = vec![0.0f32; d_out];
            debug_assert_eq!(w.len(), d_out * d_in);
            for (i, yi) in y.iter_mut().enumerate() {
                let wr = &w[i * d_in..(i + 1) * d_in];
                *yi = h.iter().zip(wr).map(|(a, b)| a * b).sum::<f32>();
            }
            if let Some(view) = self.overlay.and_then(|o| o.get(name)) {
                for (i, yi) in y.iter_mut().enumerate() {
                    for (col, theta) in view.row(i) {
                        *yi += theta * h[col];
                    }
                }
            }
            Ok(y)
        }

        /// The pre-plan KV-cached step: per-token name lookups per
        /// projection. Drives the step-parity oracle.
        pub fn forward_step(&self, token: i32, state: &mut DecodeState) -> Result<Vec<f32>> {
            let cfg = self.cfg;
            let d = cfg.d_model;
            anyhow::ensure!(state.remaining() > 0, "decode state full");
            anyhow::ensure!(token >= 0 && (token as usize) < cfg.vocab, "bad token");
            let p = state.len();
            let embed = self.p("embed")?;
            let erow = &embed[token as usize * d..(token as usize + 1) * d];
            let mut x = vec![0.0f32; d];
            // position row, same f64 math as ops::positional
            let half = d / 2;
            for i in 0..half {
                let ang = p as f64 / (10000f64).powf(2.0 * i as f64 / d as f64);
                x[i] = ang.sin() as f32;
                x[half + i] = ang.cos() as f32;
            }
            for j in 0..d {
                x[j] += erow[j];
            }
            let (nh, hd) = (cfg.n_heads, d / cfg.n_heads);
            let scale = 1.0 / (hd as f32).sqrt();
            let mut h = vec![0.0f32; d];
            for l in 0..cfg.n_layers {
                ops::rmsnorm(&x, self.p(&format!("l{l}.ln1"))?, &mut h);
                let q = self.proj_step(&h, &format!("l{l}.wq"), d, d)?;
                let kk = self.proj_step(&h, &format!("l{l}.wk"), d, d)?;
                let vv = self.proj_step(&h, &format!("l{l}.wv"), d, d)?;
                state.k[l].row_mut(p).copy_from_slice(&kk);
                state.v[l].row_mut(p).copy_from_slice(&vv);
                let mut att = vec![0.0f32; d];
                let mut scores = vec![0.0f32; p + 1];
                for head in 0..nh {
                    let qh = &q[head * hd..(head + 1) * hd];
                    for (ki, s) in scores.iter_mut().enumerate() {
                        let krow = &state.k[l].row(ki)[head * hd..(head + 1) * hd];
                        *s = qh.iter().zip(krow).map(|(a, b)| a * b).sum::<f32>() * scale;
                    }
                    let mx = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                    let mut sum = 0.0f32;
                    for s in scores.iter_mut() {
                        *s = (*s - mx).exp();
                        sum += *s;
                    }
                    for s in scores.iter_mut() {
                        *s /= sum;
                    }
                    let orow = &mut att[head * hd..(head + 1) * hd];
                    for (ki, &w) in scores.iter().enumerate() {
                        if w == 0.0 {
                            continue;
                        }
                        let vrow = &state.v[l].row(ki)[head * hd..(head + 1) * hd];
                        for j in 0..hd {
                            orow[j] += w * vrow[j];
                        }
                    }
                }
                let o = self.proj_step(&att, &format!("l{l}.wo"), d, d)?;
                for j in 0..d {
                    x[j] += o[j];
                }
                ops::rmsnorm(&x, self.p(&format!("l{l}.ln2"))?, &mut h);
                let mut m = self.proj_step(&h, &format!("l{l}.w1"), cfg.d_ff, d)?;
                for v in m.iter_mut() {
                    *v = ops::silu(*v);
                }
                let mm = self.proj_step(&m, &format!("l{l}.w2"), d, cfg.d_ff)?;
                for j in 0..d {
                    x[j] += mm[j];
                }
            }
            state.len += 1;
            let mut out = vec![0.0f32; d];
            ops::rmsnorm(&x, self.p("ln_f")?, &mut out);
            let mut logits = vec![0.0f32; cfg.vocab];
            for (t, lg) in logits.iter_mut().enumerate() {
                let er = &embed[t * d..(t + 1) * d];
                *lg = out.iter().zip(er).map(|(a, b)| a * b).sum::<f32>();
            }
            Ok(logits)
        }
    }
}

/// One measured cell of the matrix.
#[derive(Debug, Clone)]
pub struct ForwardCase {
    pub size: String,
    /// "merged" (dense) or "bypass" (sparse overlay).
    pub path: String,
    /// "plan" or "legacy".
    pub resolve: String,
    pub threads: usize,
    pub ms_per_forward: f64,
    /// Batched forwards per second.
    pub forwards_per_s: f64,
}

/// One full forward-bench run.
pub struct ForwardBenchReport {
    pub batch: usize,
    /// The "multi" thread count of the matrix (1 collapses it).
    pub threads: usize,
    /// Size the headline speedups anchor on ("micro" when present).
    pub anchor: String,
    pub results: Vec<BenchResult>,
    pub cases: Vec<ForwardCase>,
    /// anchor/merged: plan @ `threads` vs plan @ 1 (CI floor 1.5× on micro
    /// when threads ≥ 2).
    pub micro_mt_vs_st: f64,
    /// anchor/merged: plan @ `threads` vs LEGACY @ 1 — the acceptance
    /// number (≥ 2× on micro at 4 threads, batch 8).
    pub micro_plan_mt_vs_legacy_st: f64,
    /// Persistent-pool vs scoped-spawn GEMM on the anchor size's
    /// small-batch matmul (`[batch, d_model] × [d_ff, d_model]ᵀ`) —
    /// spawn_ms / pool_ms, so ≥ 1 means the pool won. NaN when the matrix
    /// ran single-threaded (no spawn baseline to compare).
    pub pool_vs_spawn: f64,
    /// `Kernel::Blocked` vs `Kernel::Scalar` (f32) on the same matmul —
    /// scalar_ms / blocked_ms, so ≥ 1 means blocking won (the ISSUE-7
    /// floor, asserted by the bench binary on micro).
    pub blocked_vs_scalar: f64,
    /// Backbone dtype of the quant e2e cells ("f32" = none were run).
    pub backbone_dtype: String,
}

impl ForwardBenchReport {
    fn case(&self, size: &str, path: &str, resolve: &str, threads: usize) -> Option<&ForwardCase> {
        self.cases.iter().find(|c| {
            c.size == size && c.path == path && c.resolve == resolve && c.threads == threads
        })
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        for r in &self.results {
            out.push_str(&r.render());
            out.push('\n');
        }
        out.push_str(&format!(
            "forward {} b={}: plan×{} vs plan×1 {:.2}×, plan×{} vs legacy×1 {:.2}×\n",
            self.anchor, self.batch, self.threads, self.micro_mt_vs_st, self.threads,
            self.micro_plan_mt_vs_legacy_st,
        ));
        out.push_str(&format!(
            "kernel {} m={}: blocked gemm is {:.2}× the scalar loop\n",
            self.anchor, self.batch, self.blocked_vs_scalar,
        ));
        if self.pool_vs_spawn.is_finite() {
            out.push_str(&format!(
                "kernel {} m={}: pooled gemm is {:.2}× the scoped-spawn baseline\n",
                self.anchor, self.batch, self.pool_vs_spawn,
            ));
        }
        out
    }

    /// Stable JSON blob for the CI bench artifact (`BENCH_forward.json`;
    /// schema documented in `docs/performance.md`).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("bench", "forward_bench");
        j.set("batch", self.batch);
        j.set("threads", self.threads);
        let mut cases = Vec::new();
        for c in &self.cases {
            let mut o = Json::obj();
            o.set("size", c.size.as_str());
            o.set("path", c.path.as_str());
            o.set("resolve", c.resolve.as_str());
            o.set("threads", c.threads);
            o.set("ms_per_forward", c.ms_per_forward);
            o.set("forwards_per_s", c.forwards_per_s);
            cases.push(o);
        }
        j.set("cases", Json::Arr(cases));
        j.set("anchor", self.anchor.as_str());
        j.set("backbone_dtype", self.backbone_dtype.as_str());
        j.set("micro_mt_vs_st", self.micro_mt_vs_st);
        j.set("micro_plan_mt_vs_legacy_st", self.micro_plan_mt_vs_legacy_st);
        // null (not NaN) when single-threaded, via fmt_num's non-finite rule
        j.set("pool_vs_spawn_matmul", self.pool_vs_spawn);
        j.set("blocked_vs_scalar", self.blocked_vs_scalar);
        j
    }
}

/// [`run_with_dtype`] at f32 (no quant e2e cells) — the historical entry.
pub fn run(sizes: &[&str], batch: usize, threads: usize, quick: bool) -> Result<ForwardBenchReport> {
    run_with_dtype(sizes, batch, threads, quick, BackboneDtype::F32)
}

/// Run the forward bench over `sizes` at `batch`, measuring legacy @ 1
/// thread, plan @ 1 thread, and plan @ `threads` for merged AND bypass,
/// plus the dtype×kernel matmul matrix on the anchor size. Plan-vs-legacy
/// parity (≤ 1e-6; bit-identical in practice) is asserted for every cell
/// before timing, and kernel cells assert Scalar ≡ Blocked ≡ pooled
/// bitwise per dtype. With a quantized `dtype`, each size additionally
/// gets a `path: "quant"` e2e cell over the quantized backbone, gated on
/// the documented logit-deviation bound (`BackboneDtype::logit_tol`) vs
/// the f32 plan.
pub fn run_with_dtype(
    sizes: &[&str],
    batch: usize,
    threads: usize,
    quick: bool,
    dtype: BackboneDtype,
) -> Result<ForwardBenchReport> {
    anyhow::ensure!(batch >= 1, "forward bench needs batch >= 1");
    let threads = threads.max(1);
    let b = if quick { Bench::quick() } else { Bench::default() };
    let mut results = Vec::new();
    let mut cases = Vec::new();
    // the tentpole shape: ONE persistent pool for the whole bench run (its
    // workers are spawned here once); the serial cells use the shared
    // serial pool, the bit-identical baseline
    let pool = KernelPool::new(threads);
    let serial = KernelPool::serial();

    for &size in sizes {
        let cfg = presets::model(size).ok_or_else(|| anyhow!("unknown size {size:?}"))?;
        anyhow::ensure!(cfg.n_classes == 0, "forward bench needs decoder sizes");
        let mut rng = Rng::new(7);
        let backbone = init_params(&cfg, &mut rng);
        let deltas = super::serve_bench::synth_adapter(&cfg, &backbone, 1, 0xF0 + batch as u64)?;
        let overlay = DeltaOverlay::new(&deltas);
        let tokens: Vec<i32> = (0..batch * cfg.seq)
            .map(|i| 4 + ((i * 7) % (cfg.vocab - 4)) as i32)
            .collect();
        let pad = vec![1.0f32; batch * cfg.seq];
        let last: Vec<i32> = (0..batch).map(|i| ((cfg.seq - 1 - i % 4) as i32)).collect();

        for path in ["merged", "bypass"] {
            let ov = (path == "bypass").then_some(&overlay);
            let lm = legacy::LegacyModel { cfg: &cfg, params: &backbone, overlay: ov };

            // parity gate before timing: the plan must reproduce the
            // pre-refactor logits (bit-identical kernels; ≤1e-6 contract)
            let want = lm.lm_logits_at(&tokens, &pad, &last, batch)?;
            for (t, pl) in [(1usize, &serial), (threads, &pool)] {
                let got = PlannedModel::resolve(&cfg, &backbone, ov, pl)?
                    .lm_logits_at(&tokens, &pad, &last, batch)?;
                let diff = want.max_abs_diff(&got);
                anyhow::ensure!(
                    diff <= 1e-6,
                    "{size}/{path}: plan(threads={t}) vs legacy logit diff {diff}"
                );
            }

            let mut measure = |resolve: &str, t: usize, f: &mut dyn FnMut()| {
                let r = b.run(&format!("forward/{resolve} {size} {path} b={batch} t={t}"), f);
                cases.push(ForwardCase {
                    size: size.to_string(),
                    path: path.to_string(),
                    resolve: resolve.to_string(),
                    threads: t,
                    ms_per_forward: r.per_iter_ms(),
                    forwards_per_s: r.throughput(1.0),
                });
                results.push(r);
            };

            measure("legacy", 1, &mut || {
                std::hint::black_box(
                    lm.lm_logits_at(&tokens, &pad, &last, batch).unwrap().numel(),
                );
            });
            // plan resolution is INSIDE the measured iteration: the honest
            // comparison includes the (cheap) per-call resolve the serving
            // worker pays per batch — but NOT pool construction, which the
            // serving engine pays once per server, not per batch
            measure("plan", 1, &mut || {
                let p = PlannedModel::resolve(&cfg, &backbone, ov, &serial).unwrap();
                std::hint::black_box(p.lm_logits_at(&tokens, &pad, &last, batch).unwrap().numel());
            });
            if threads > 1 {
                measure("plan", threads, &mut || {
                    let p = PlannedModel::resolve(&cfg, &backbone, ov, &pool).unwrap();
                    std::hint::black_box(
                        p.lm_logits_at(&tokens, &pad, &last, batch).unwrap().numel(),
                    );
                });
            }
        }

        // quant e2e cell: the merged forward over the quantized backbone,
        // gated on the documented logit bound vs the f32 plan (and on
        // pooled ≡ serial bitwise — the partition invariant holds for
        // every dtype)
        if dtype.is_quantized() {
            let qstore = QuantStore::from_store(&backbone, dtype)?;
            let want = PlannedModel::resolve(&cfg, &backbone, None, &serial)?
                .lm_logits_at(&tokens, &pad, &last, batch)?;
            let got = PlannedModel::resolve_from(&cfg, &qstore, None, &serial)?
                .lm_logits_at(&tokens, &pad, &last, batch)?;
            let scale = want.data.iter().fold(1.0f32, |m, v| m.max(v.abs()));
            let tol = dtype.logit_tol() * scale;
            let diff = want.max_abs_diff(&got);
            anyhow::ensure!(
                diff <= tol,
                "{size}: {} logits deviate {diff} from f32 (bound {tol})",
                dtype.name()
            );
            let (qt, qpool) = if threads > 1 { (threads, &pool) } else { (1, &serial) };
            let pooled = PlannedModel::resolve_from(&cfg, &qstore, None, qpool)?
                .lm_logits_at(&tokens, &pad, &last, batch)?;
            anyhow::ensure!(
                got.data == pooled.data,
                "{size}: pooled {} forward diverged from serial",
                dtype.name()
            );
            let r = b.run(&format!("forward/quant-{} {size} b={batch} t={qt}", dtype.name()), &mut || {
                let p = PlannedModel::resolve_from(&cfg, &qstore, None, qpool).unwrap();
                std::hint::black_box(p.lm_logits_at(&tokens, &pad, &last, batch).unwrap().numel());
            });
            cases.push(ForwardCase {
                size: size.to_string(),
                path: "quant".to_string(),
                resolve: dtype.name().to_string(),
                threads: qt,
                ms_per_forward: r.per_iter_ms(),
                forwards_per_s: r.throughput(1.0),
            });
            results.push(r);
        }
    }

    let pick = |cases: &[ForwardCase], size: &str, resolve: &str, t: usize| -> f64 {
        cases
            .iter()
            .find(|c| c.size == size && c.path == "merged" && c.resolve == resolve && c.threads == t)
            .map(|c| c.ms_per_forward)
            .unwrap_or(f64::NAN)
    };
    // the acceptance size is micro; fall back to the last size when the
    // matrix was run without it (lib tests use nano only)
    let anchor = if sizes.contains(&"micro") { "micro" } else { sizes.last().copied().unwrap_or("nano") };

    // kernel-level dtype×kernel matrix on the anchor's w1-shaped matmul
    // (`[batch, d_model] × [d_ff, d_model]ᵀ`): Scalar vs Blocked per dtype
    // (always measured), plus the pooled-vs-spawn pair when the matrix ran
    // multi-threaded. Before timing, every kernel×pool combination is
    // asserted bitwise against its dtype's serial Scalar oracle.
    let acfg = presets::model(anchor).ok_or_else(|| anyhow!("unknown size {anchor:?}"))?;
    let (m, k, n) = (batch, acfg.d_model, acfg.d_ff);
    let mut rng = Rng::new(41);
    let a = Tensor::randn(&[m, k], 1.0, &mut rng);
    let w = Tensor::randn(&[n, k], 1.0, &mut rng);
    let (kt, kpool) = if threads > 1 { (threads, &pool) } else { (1, &serial) };
    let mut want = vec![0.0f32; m * n];
    Kernel::Scalar.gemm_nt(&a.data, m, k, MatRef::F32(&w.data), n, &mut want, &serial);
    let mut got = vec![0.0f32; m * n];
    for kern in [Kernel::Scalar, Kernel::Blocked] {
        got.fill(0.0);
        kern.gemm_nt(&a.data, m, k, MatRef::F32(&w.data), n, &mut got, kpool);
        anyhow::ensure!(want == got, "{kern:?} gemm diverged from the serial scalar oracle");
    }
    got.fill(0.0);
    ops::nt_into_scoped(&a.data, m, k, &w.data, n, &mut got, kt);
    anyhow::ensure!(want == got, "scoped gemm diverged from serial");
    let qb16 = QuantMat::quantize(BackboneDtype::Bf16, n, k, &w.data);
    let qi8 = QuantMat::quantize(BackboneDtype::I8, n, k, &w.data);
    for (nm, q) in [("bf16", &qb16), ("int8", &qi8)] {
        let mut qwant = vec![0.0f32; m * n];
        Kernel::Scalar.gemm_nt(&a.data, m, k, q.as_ref(), n, &mut qwant, &serial);
        got.fill(0.0);
        Kernel::Blocked.gemm_nt(&a.data, m, k, q.as_ref(), n, &mut got, kpool);
        anyhow::ensure!(qwant == got, "{nm} blocked gemm diverged from its scalar oracle");
    }
    let mut out = vec![0.0f32; m * n];
    let mut measure_kernel = |resolve: &str, f: &mut dyn FnMut(&mut [f32])| {
        let r = b.run(&format!("matmul/{resolve} {anchor} m={m} t={kt}"), &mut || {
            f(&mut out);
            std::hint::black_box(out.len());
        });
        cases.push(ForwardCase {
            size: anchor.to_string(),
            path: "kernel".to_string(),
            resolve: resolve.to_string(),
            threads: kt,
            ms_per_forward: r.per_iter_ms(),
            forwards_per_s: r.throughput(1.0),
        });
        let ms = r.per_iter_ms();
        results.push(r);
        ms
    };
    let scalar_ms = measure_kernel("scalar", &mut |o| {
        Kernel::Scalar.gemm_nt(&a.data, m, k, MatRef::F32(&w.data), n, o, kpool)
    });
    let blocked_ms = measure_kernel("blocked", &mut |o| {
        Kernel::Blocked.gemm_nt(&a.data, m, k, MatRef::F32(&w.data), n, o, kpool)
    });
    measure_kernel("blocked-bf16", &mut |o| {
        Kernel::Blocked.gemm_nt(&a.data, m, k, qb16.as_ref(), n, o, kpool)
    });
    measure_kernel("blocked-int8", &mut |o| {
        Kernel::Blocked.gemm_nt(&a.data, m, k, qi8.as_ref(), n, o, kpool)
    });
    let blocked_vs_scalar = scalar_ms / blocked_ms;
    let mut pool_vs_spawn = f64::NAN;
    if threads > 1 {
        let pool_ms = measure_kernel("pool", &mut |o| {
            ops::gemm_nt(&a.data, m, k, MatRef::F32(&w.data), n, o, &pool)
        });
        let spawn_ms = measure_kernel("spawn", &mut |o| {
            ops::nt_into_scoped(&a.data, m, k, &w.data, n, o, threads)
        });
        pool_vs_spawn = spawn_ms / pool_ms;
    }

    let plan_st = pick(&cases, anchor, "plan", 1);
    let plan_mt = if threads > 1 { pick(&cases, anchor, "plan", threads) } else { plan_st };
    let legacy_st = pick(&cases, anchor, "legacy", 1);
    Ok(ForwardBenchReport {
        batch,
        threads,
        anchor: anchor.to_string(),
        results,
        cases,
        micro_mt_vs_st: plan_st / plan_mt,
        micro_plan_mt_vs_legacy_st: legacy_st / plan_mt,
        pool_vs_spawn,
        blocked_vs_scalar,
        backbone_dtype: dtype.name().to_string(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Structure + parity gates (run on nano to stay fast); the hard CI
    /// speedup floors are asserted by the bench binary, not here, so test
    /// runs stay robust to loaded machines.
    #[test]
    fn quick_forward_bench_runs_with_parity() {
        let r = run(&["nano"], 4, 2, true).unwrap();
        // 2 paths × (legacy + plan@1 + plan@2) + the 4 dtype×kernel cells
        // + the 2 pooled-vs-spawn kernel cells
        assert_eq!(r.cases.len(), 12);
        assert!(r.cases.iter().all(|c| c.ms_per_forward > 0.0 && c.forwards_per_s > 0.0));
        assert!(r.case("nano", "bypass", "plan", 2).is_some());
        for kernel in ["scalar", "blocked", "blocked-bf16", "blocked-int8", "pool", "spawn"] {
            assert!(r.case("nano", "kernel", kernel, 2).is_some(), "missing kernel cell {kernel}");
        }
        assert!(r.micro_mt_vs_st > 0.0 && r.micro_plan_mt_vs_legacy_st > 0.0);
        // the ratios are recorded (their >= 1 floors are asserted by the
        // bench binary on micro, not here — module tests stay
        // load-insensitive)
        assert!(r.pool_vs_spawn > 0.0);
        assert!(r.blocked_vs_scalar > 0.0);
        assert_eq!(r.backbone_dtype, "f32");
        let j = r.to_json();
        assert_eq!(j.at(&["bench"]).and_then(Json::as_str), Some("forward_bench"));
        assert_eq!(j.at(&["cases"]).and_then(|c| c.as_arr()).map(|a| a.len()), Some(12));
        assert!(j.at(&["micro_plan_mt_vs_legacy_st"]).and_then(Json::as_f64).is_some());
        assert!(j.at(&["pool_vs_spawn_matmul"]).and_then(Json::as_f64).is_some());
        assert!(j.at(&["blocked_vs_scalar"]).and_then(Json::as_f64).is_some());
        assert_eq!(j.at(&["backbone_dtype"]).and_then(Json::as_str), Some("f32"));
        assert_eq!(r.anchor, "nano", "anchor falls back to the measured size");
        assert!(r.render().contains("forward nano b=4"), "{}", r.render());
        assert!(r.render().contains("kernel nano"), "{}", r.render());
        // single-threaded runs keep the dtype×kernel cells (serial pool)
        // but have no spawn baseline: that ratio is NaN, which fmt_num
        // serializes as null (valid JSON)
        let r1 = run(&["nano"], 2, 1, true).unwrap();
        assert!(r1.pool_vs_spawn.is_nan());
        assert!(r1.blocked_vs_scalar > 0.0);
        assert_eq!(r1.cases.len(), 8, "no pool/spawn cells without a multi-thread matrix");
        assert!(r1.case("nano", "kernel", "blocked-int8", 1).is_some());
    }

    /// Quantized-backbone e2e cells: the merged forward over bf16/int8
    /// backbones passes the documented logit gate and lands one `quant`
    /// cell per size (the hard gates run inside `run_with_dtype`).
    #[test]
    fn quant_forward_bench_gates_and_measures() {
        for (dtype, name) in
            [(BackboneDtype::Bf16, "bf16"), (BackboneDtype::I8, "int8")]
        {
            let r = run_with_dtype(&["nano"], 2, 1, true, dtype).unwrap();
            assert_eq!(r.cases.len(), 9, "{name}: 8 base cells + 1 quant cell");
            assert!(r.case("nano", "quant", name, 1).is_some());
            assert_eq!(r.backbone_dtype, name);
            let j = r.to_json();
            assert_eq!(j.at(&["backbone_dtype"]).and_then(Json::as_str), Some(name));
        }
    }

    /// The legacy step oracle agrees with itself across state reuse (sanity
    /// for the parity tests that compare it against the planned step).
    #[test]
    fn legacy_step_matches_planned_step_exactly() {
        use crate::model::{DecodeState, RefModel};
        let cfg = presets::model("nano").unwrap();
        let params = init_params(&cfg, &mut Rng::new(3));
        let lm = legacy::LegacyModel { cfg: &cfg, params: &params, overlay: None };
        let plan = RefModel::new(&cfg, &params).plan().unwrap();
        let mut sa = DecodeState::new(&cfg);
        let mut sb = DecodeState::new(&cfg);
        for (i, tok) in (0..10).map(|i| 4 + (i * 3) % 40).enumerate() {
            let a = lm.forward_step(tok, &mut sa).unwrap();
            let b = plan.forward_step(tok, &mut sb).unwrap();
            assert_eq!(a, b, "position {i}: legacy vs planned step logits");
        }
    }
}
