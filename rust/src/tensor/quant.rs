//! Quantized frozen-backbone storage and the [`MatRef`] weight view.
//!
//! NeuroAda freezes the backbone by construction and trains only sparse
//! f32/bf16 bypass deltas, so the resident backbone can be stored at
//! reduced precision with zero effect on training semantics — the QLoRA
//! pattern (quantized frozen base + full-precision adapters). This module
//! owns the three storage dtypes behind one view type:
//!
//! * [`MatRef`] — a borrowed row-major matrix in any dtype. Every GEMM in
//!   the crate takes one (`ops::gemm_nt`), and `ProjPlan`/`PlannedModel`
//!   hold them, so forward, batched attention, and the batch-1 decode step
//!   all run on quantized backbones unchanged.
//! * [`QuantMat`] / [`QuantStore`] — owned quantized tensors keyed like a
//!   `ValueStore`. Rank-2 f32 parameters are quantized; rank-1 vectors
//!   (layer norms) and integer tensors stay exact, so normalization math is
//!   untouched by the dtype knob.
//!
//! Dtype semantics:
//! * **bf16** — round-to-nearest-even truncation (`tensor::bf16`);
//!   dequantization is exact (bf16 ⊂ f32), so per-element error is bounded
//!   by `|x| · BF16_EPS` and bf16-representable values round-trip bitwise.
//! * **int8** — symmetric per-row scales: `scale = max|row| / 127`,
//!   `q = round(x / scale)` clamped to ±127, dequant `q · scale`.
//!   Per-element error is bounded by `scale / 2`; an all-zero row stores
//!   scale 0 and round-trips exactly.
//!
//! Bytes per dtype (the serving memory formula, cross-checked against
//! `peft::memory::backbone_resident_bytes`): f32 = 4·P; bf16 = 2·P_mat +
//! 4·P_vec; int8 = 1·P_mat + 4·rows (scales) + 4·P_vec.
//!
//! The dequantize-in-register dot kernels live here next to the formats
//! ([`nt_dot_bf16`], [`nt_dot_i8`]); `ops::gemm_nt`'s blocked and scalar
//! kernels share them per dtype, so kernel choice never changes results
//! (bit-identical per dtype by construction).

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::runtime::{Value, ValueStore};
use crate::tensor::bf16;

/// Storage dtype of a resident (frozen) backbone.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackboneDtype {
    #[default]
    F32,
    Bf16,
    I8,
}

impl BackboneDtype {
    /// Parse the CLI knob (`--backbone-dtype f32|bf16|int8`).
    pub fn parse(s: &str) -> Result<BackboneDtype, String> {
        match s {
            "f32" => Ok(BackboneDtype::F32),
            "bf16" => Ok(BackboneDtype::Bf16),
            "int8" | "i8" => Ok(BackboneDtype::I8),
            other => Err(format!("unknown backbone dtype {other:?} (want f32 | bf16 | int8)")),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            BackboneDtype::F32 => "f32",
            BackboneDtype::Bf16 => "bf16",
            BackboneDtype::I8 => "int8",
        }
    }

    /// Bytes per matrix element (int8 scales are accounted per row).
    pub fn mat_elem_bytes(self) -> u64 {
        match self {
            BackboneDtype::F32 => 4,
            BackboneDtype::Bf16 => 2,
            BackboneDtype::I8 => 1,
        }
    }

    pub fn is_quantized(self) -> bool {
        self != BackboneDtype::F32
    }

    /// Documented end-to-end logit-deviation bound for a forward over a
    /// backbone quantized at this dtype, as a fraction of the f32 run's
    /// max |logit|. These are regression gates (used by the bench binaries
    /// and the quant acceptance tests), deliberately generous vs the
    /// observed deviation: per-element weight error is ≤ `BF16_EPS` (bf16)
    /// / `scale/2` (int8) and RMSNorm re-normalizes between layers, so a
    /// breach means quantization broke, not that the model drifted.
    pub fn logit_tol(self) -> f32 {
        match self {
            BackboneDtype::F32 => 0.0,
            BackboneDtype::Bf16 => 0.05,
            BackboneDtype::I8 => 0.15,
        }
    }
}

/// A borrowed row-major matrix in any backbone dtype — the one weight-view
/// type the GEMM dispatch (`ops::gemm_nt`) and the planned forward accept.
///
/// `MatRef` carries no dimensions; callers supply `cols` implicitly through
/// the output/input slice lengths exactly as the raw-slice kernels always
/// did.
#[derive(Debug, Clone, Copy)]
pub enum MatRef<'a> {
    F32(&'a [f32]),
    Bf16(&'a [u16]),
    I8 {
        data: &'a [i8],
        /// One symmetric scale per matrix row.
        scales: &'a [f32],
    },
}

impl<'a> MatRef<'a> {
    /// Total element count (rows · cols).
    pub fn len(&self) -> usize {
        match self {
            MatRef::F32(d) => d.len(),
            MatRef::Bf16(d) => d.len(),
            MatRef::I8 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> BackboneDtype {
        match self {
            MatRef::F32(_) => BackboneDtype::F32,
            MatRef::Bf16(_) => BackboneDtype::Bf16,
            MatRef::I8 { .. } => BackboneDtype::I8,
        }
    }

    /// Dequantize row `i` into `out` (`out.len()` is the column count).
    /// The f32 path is a bitwise copy.
    pub fn read_row(&self, i: usize, out: &mut [f32]) {
        let c = out.len();
        match self {
            MatRef::F32(d) => out.copy_from_slice(&d[i * c..(i + 1) * c]),
            MatRef::Bf16(d) => {
                for (o, &h) in out.iter_mut().zip(&d[i * c..(i + 1) * c]) {
                    *o = bf16::to_f32(h);
                }
            }
            MatRef::I8 { data, scales } => {
                let s = scales[i];
                for (o, &q) in out.iter_mut().zip(&data[i * c..(i + 1) * c]) {
                    *o = q as f32 * s;
                }
            }
        }
    }

    /// `row(i) · x` with `x.len()` columns — the batch-1 decode-step dot.
    ///
    /// The f32 path is the sequential zip-sum the pre-`MatRef` decode step
    /// used, kept verbatim so the step stays bitwise identical to its
    /// legacy oracle; bf16/int8 dequantize in-register through the same
    /// 4-wide kernels the batched GEMM uses, so the step and batch paths
    /// agree bitwise per dtype.
    pub fn dot_row(&self, i: usize, x: &[f32]) -> f32 {
        let c = x.len();
        match self {
            MatRef::F32(d) => {
                let wr = &d[i * c..(i + 1) * c];
                x.iter().zip(wr).map(|(a, b)| a * b).sum::<f32>()
            }
            MatRef::Bf16(d) => nt_dot_bf16(x, &d[i * c..(i + 1) * c], c),
            MatRef::I8 { data, scales } => nt_dot_i8(x, &data[i * c..(i + 1) * c], c, scales[i]),
        }
    }
}

/// bf16 dot with dequantize-in-register: 4-wide manual unroll mirroring the
/// f32 `nt_dot` structure (the autovectorizer does the rest). Bit-identical
/// to running the f32 kernel on the exactly-dequantized matrix.
#[inline]
pub(crate) fn nt_dot_bf16(ar: &[f32], br: &[u16], k: usize) -> f32 {
    let mut acc = 0.0f32;
    let mut t = 0;
    while t + 4 <= k {
        acc += ar[t] * bf16::to_f32(br[t])
            + ar[t + 1] * bf16::to_f32(br[t + 1])
            + ar[t + 2] * bf16::to_f32(br[t + 2])
            + ar[t + 3] * bf16::to_f32(br[t + 3]);
        t += 4;
    }
    while t < k {
        acc += ar[t] * bf16::to_f32(br[t]);
        t += 1;
    }
    acc
}

/// int8 dot with the per-row scale applied once at the end: the integer
/// codes widen to f32 in-register and accumulate 4-wide, then one multiply
/// by `scale` — not per element.
#[inline]
pub(crate) fn nt_dot_i8(ar: &[f32], br: &[i8], k: usize, scale: f32) -> f32 {
    let mut acc = 0.0f32;
    let mut t = 0;
    while t + 4 <= k {
        acc += ar[t] * br[t] as f32
            + ar[t + 1] * br[t + 1] as f32
            + ar[t + 2] * br[t + 2] as f32
            + ar[t + 3] * br[t + 3] as f32;
        t += 4;
    }
    while t < k {
        acc += ar[t] * br[t] as f32;
        t += 1;
    }
    acc * scale
}

/// Owned quantized storage of one rank-2 matrix.
#[derive(Debug, Clone)]
pub struct QuantMat {
    pub rows: usize,
    pub cols: usize,
    data: QuantData,
}

#[derive(Debug, Clone)]
enum QuantData {
    Bf16(Vec<u16>),
    I8 { data: Vec<i8>, scales: Vec<f32> },
}

impl QuantMat {
    /// Quantize a row-major `[rows, cols]` f32 matrix. `dtype` must be a
    /// quantized dtype (an f32 "quantization" would just be the input).
    pub fn quantize(dtype: BackboneDtype, rows: usize, cols: usize, data: &[f32]) -> QuantMat {
        assert_eq!(data.len(), rows * cols, "matrix is [rows, cols]");
        let qd = match dtype {
            BackboneDtype::F32 => panic!("QuantMat::quantize: f32 is not a quantized dtype"),
            BackboneDtype::Bf16 => QuantData::Bf16(bf16::pack(data)),
            BackboneDtype::I8 => {
                let mut q = vec![0i8; data.len()];
                let mut scales = vec![0.0f32; rows];
                for (i, scale) in scales.iter_mut().enumerate() {
                    let row = &data[i * cols..(i + 1) * cols];
                    let mx = row.iter().fold(0.0f32, |m, v| m.max(v.abs()));
                    *scale = mx / 127.0;
                    if *scale > 0.0 {
                        let inv = 1.0 / *scale;
                        for (o, &v) in q[i * cols..(i + 1) * cols].iter_mut().zip(row) {
                            *o = (v * inv).round().clamp(-127.0, 127.0) as i8;
                        }
                    }
                }
                QuantData::I8 { data: q, scales }
            }
        };
        QuantMat { rows, cols, data: qd }
    }

    pub fn dtype(&self) -> BackboneDtype {
        match &self.data {
            QuantData::Bf16(_) => BackboneDtype::Bf16,
            QuantData::I8 { .. } => BackboneDtype::I8,
        }
    }

    pub fn as_ref(&self) -> MatRef<'_> {
        match &self.data {
            QuantData::Bf16(d) => MatRef::Bf16(d),
            QuantData::I8 { data, scales } => MatRef::I8 { data, scales },
        }
    }

    /// Resident bytes: codes plus (for int8) the per-row f32 scales.
    pub fn bytes(&self) -> u64 {
        match &self.data {
            QuantData::Bf16(d) => 2 * d.len() as u64,
            QuantData::I8 { data, scales } => (data.len() + 4 * scales.len()) as u64,
        }
    }

    /// Dequantize back to a dense f32 matrix.
    pub fn dequant(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.rows * self.cols];
        let r = self.as_ref();
        for i in 0..self.rows {
            r.read_row(i, &mut out[i * self.cols..(i + 1) * self.cols]);
        }
        out
    }
}

/// A quantized `ValueStore`: rank-2 f32 parameters held as [`QuantMat`]s,
/// everything else (rank-1 norm vectors, integer tensors) verbatim.
#[derive(Debug, Clone)]
pub struct QuantStore {
    dtype: BackboneDtype,
    mats: BTreeMap<String, QuantMat>,
    /// The unquantized remainder, stored as a plain [`ValueStore`].
    full: ValueStore,
}

impl QuantStore {
    /// Quantize every rank-2 f32 tensor of `store` to `dtype` (which must
    /// be bf16 or int8 — an f32 backbone stays a `ValueStore`).
    pub fn from_store(store: &ValueStore, dtype: BackboneDtype) -> Result<QuantStore> {
        if !dtype.is_quantized() {
            bail!("QuantStore wants a quantized dtype, got {}", dtype.name());
        }
        let mut mats = BTreeMap::new();
        let mut full = ValueStore::new();
        for name in store.names() {
            match store.get(name)? {
                Value::F32 { shape, data } if shape.len() == 2 => {
                    let q = QuantMat::quantize(dtype, shape[0], shape[1], data);
                    mats.insert(name.clone(), q);
                }
                v => full.insert(name.clone(), v.clone()),
            }
        }
        Ok(QuantStore { dtype, mats, full })
    }

    pub fn dtype(&self) -> BackboneDtype {
        self.dtype
    }

    /// Entry by full key, as a [`MatRef`] (quantized matrices and exact f32
    /// leftovers both resolve; integer tensors error).
    pub fn mat(&self, name: &str) -> Result<MatRef<'_>> {
        if let Some(q) = self.mats.get(name) {
            return Ok(q.as_ref());
        }
        Ok(MatRef::F32(self.full.get(name)?.as_f32()?))
    }

    /// Exact-f32 entry by full key (layer norms etc.); quantized matrices
    /// error — they have no resident f32 form.
    pub fn vec_f32(&self, name: &str) -> Result<&[f32]> {
        if self.mats.contains_key(name) {
            bail!("{name:?} is quantized ({}); no resident f32 form", self.dtype.name());
        }
        self.full.get(name)?.as_f32()
    }

    pub fn contains(&self, name: &str) -> bool {
        self.mats.contains_key(name) || self.full.contains(name)
    }

    pub fn len(&self) -> usize {
        self.mats.len() + self.full.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Resident bytes: quantized codes + scales + the exact remainder.
    pub fn total_bytes(&self) -> u64 {
        self.mats.values().map(QuantMat::bytes).sum::<u64>() + self.full.total_bytes()
    }

    /// Dequantize everything back into a dense f32 [`ValueStore`] (the HLO
    /// backend and merge-time delta application run on this).
    pub fn to_f32_store(&self) -> ValueStore {
        let mut out = self.full.clone();
        for (name, q) in &self.mats {
            out.insert_f32(name.clone(), &[q.rows, q.cols], q.dequant());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::bf16::BF16_EPS;
    use crate::tensor::Tensor;
    use crate::testing::{prop_check, PropConfig};
    use crate::util::rng::Rng;

    #[test]
    fn dtype_parses_and_names() {
        for (s, d) in
            [("f32", BackboneDtype::F32), ("bf16", BackboneDtype::Bf16), ("int8", BackboneDtype::I8)]
        {
            assert_eq!(BackboneDtype::parse(s).unwrap(), d);
            assert_eq!(BackboneDtype::parse(d.name()).unwrap(), d);
        }
        assert_eq!(BackboneDtype::parse("i8").unwrap(), BackboneDtype::I8);
        assert!(BackboneDtype::parse("fp4").is_err());
        assert!(!BackboneDtype::F32.is_quantized());
        assert!(BackboneDtype::Bf16.is_quantized() && BackboneDtype::I8.is_quantized());
    }

    /// Property: per-element round-trip error bounds — `|x| · BF16_EPS` for
    /// bf16, `scale/2` per row for int8 — on randomized shapes and scales.
    #[test]
    fn prop_roundtrip_error_bounded() {
        prop_check(PropConfig { cases: 48, max_size: 19, base_seed: 0x9A17 }, |rng, size| {
            let rows = 1 + rng.below(size.max(1));
            let cols = 1 + rng.below(size.max(1) * 2);
            let spread = 0.1 + rng.below(40) as f32;
            let x = Tensor::randn(&[rows, cols], spread, rng);
            for dtype in [BackboneDtype::Bf16, BackboneDtype::I8] {
                let q = QuantMat::quantize(dtype, rows, cols, &x.data);
                let back = q.dequant();
                for i in 0..rows {
                    let row = &x.data[i * cols..(i + 1) * cols];
                    let bound = match dtype {
                        BackboneDtype::Bf16 => f32::NAN, // per-element below
                        BackboneDtype::I8 => {
                            let mx = row.iter().fold(0.0f32, |m, v| m.max(v.abs()));
                            // scale/2 plus float-rounding headroom
                            mx / 127.0 * 0.5 + mx * 1e-6
                        }
                        BackboneDtype::F32 => unreachable!(),
                    };
                    for (j, (&want, &got)) in
                        row.iter().zip(&back[i * cols..(i + 1) * cols]).enumerate()
                    {
                        let err = (want - got).abs();
                        let lim = if dtype == BackboneDtype::Bf16 {
                            want.abs() * BF16_EPS
                        } else {
                            bound
                        };
                        if err > lim {
                            return Err(format!(
                                "{} [{i},{j}]: |{want} - {got}| = {err} > {lim}",
                                dtype.name()
                            ));
                        }
                    }
                }
            }
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn all_zero_row_roundtrips_exactly() {
        let x = vec![0.0f32; 12];
        for dtype in [BackboneDtype::Bf16, BackboneDtype::I8] {
            let q = QuantMat::quantize(dtype, 3, 4, &x);
            assert_eq!(q.dequant(), x, "{}", dtype.name());
        }
    }

    /// A single outlier sets the int8 row scale; the other entries still
    /// obey the scale/2 bound and the outlier itself is near-exact.
    #[test]
    fn single_outlier_row_keeps_bound() {
        let mut x = vec![0.01f32; 8];
        x[3] = 100.0;
        let q = QuantMat::quantize(BackboneDtype::I8, 1, 8, &x);
        let back = q.dequant();
        let scale = 100.0 / 127.0;
        assert!((back[3] - 100.0).abs() <= scale * 0.5);
        for (j, (&want, &got)) in x.iter().zip(&back).enumerate() {
            assert!((want - got).abs() <= scale * 0.5 + 1e-6, "[{j}] {want} vs {got}");
        }
        // the tiny entries quantize to code 0 under an outlier-driven scale
        assert_eq!(back[0], 0.0);
    }

    #[test]
    fn read_row_and_dot_row_agree_with_dequant() {
        let mut rng = Rng::new(5);
        let (rows, cols) = (7, 13);
        let x = Tensor::randn(&[rows, cols], 1.0, &mut rng);
        let act: Vec<f32> = (0..cols).map(|_| rng.normal()).collect();
        for dtype in [BackboneDtype::Bf16, BackboneDtype::I8] {
            let q = QuantMat::quantize(dtype, rows, cols, &x.data);
            let dq = q.dequant();
            let mut row = vec![0.0f32; cols];
            for i in 0..rows {
                q.as_ref().read_row(i, &mut row);
                assert_eq!(&row[..], &dq[i * cols..(i + 1) * cols], "{} row {i}", dtype.name());
                let want: f32 = act.iter().zip(&row).map(|(a, b)| a * b).sum();
                let got = q.as_ref().dot_row(i, &act);
                assert!((want - got).abs() <= 1e-4 * want.abs().max(1.0), "{} row {i}", dtype.name());
            }
        }
        // the f32 view's read/dot are bitwise
        let f = MatRef::F32(&x.data);
        let mut row = vec![0.0f32; cols];
        f.read_row(2, &mut row);
        assert_eq!(&row[..], &x.data[2 * cols..3 * cols]);
        assert_eq!(f.dot_row(2, &act), act.iter().zip(&row).map(|(a, b)| a * b).sum::<f32>());
    }

    #[test]
    fn store_quantizes_rank2_only_and_shrinks() {
        let mut s = ValueStore::new();
        let mut rng = Rng::new(11);
        s.insert_f32("params.w", &[16, 8], (0..128).map(|_| rng.normal()).collect());
        s.insert_f32("params.ln", &[8], vec![1.0; 8]);
        s.insert_i32("params.idx", &[4], vec![1, 2, 3, 4]);
        for dtype in [BackboneDtype::Bf16, BackboneDtype::I8] {
            let q = QuantStore::from_store(&s, dtype).unwrap();
            assert_eq!(q.dtype(), dtype);
            assert_eq!(q.len(), 3);
            assert!(q.contains("params.w") && q.contains("params.ln"));
            // the norm vector stays exact f32; the matrix has no f32 form
            assert_eq!(q.vec_f32("params.ln").unwrap(), &[1.0f32; 8][..]);
            assert!(q.vec_f32("params.w").is_err());
            assert_eq!(q.mat("params.w").unwrap().dtype(), dtype);
            assert_eq!(q.mat("params.ln").unwrap().dtype(), BackboneDtype::F32);
            assert!(q.total_bytes() < s.total_bytes());
            // round-trip restores shapes and the exact entries bitwise
            let back = q.to_f32_store();
            assert_eq!(back.len(), 3);
            assert_eq!(back.get("params.w").unwrap().shape(), &[16, 8]);
            assert_eq!(
                back.get("params.ln").unwrap().as_f32().unwrap(),
                s.get("params.ln").unwrap().as_f32().unwrap()
            );
        }
        assert!(QuantStore::from_store(&s, BackboneDtype::F32).is_err());
    }

    /// The acceptance byte ratio: int8 ≤ 0.5× f32 resident bytes on a
    /// realistically matrix-dominated store (and bf16 ≤ ~0.5× + vectors).
    #[test]
    fn int8_store_is_at_most_half_of_f32() {
        let mut s = ValueStore::new();
        let mut rng = Rng::new(12);
        s.insert_f32("params.embed", &[64, 32], (0..64 * 32).map(|_| rng.normal()).collect());
        s.insert_f32("params.w", &[32, 32], (0..32 * 32).map(|_| rng.normal()).collect());
        s.insert_f32("params.ln", &[32], vec![1.0; 32]);
        let f32_bytes = s.total_bytes();
        let i8_bytes = QuantStore::from_store(&s, BackboneDtype::I8).unwrap().total_bytes();
        let bf16_bytes = QuantStore::from_store(&s, BackboneDtype::Bf16).unwrap().total_bytes();
        assert!(
            i8_bytes * 2 <= f32_bytes,
            "int8 {i8_bytes} B must be <= 0.5x f32 {f32_bytes} B"
        );
        assert!(bf16_bytes < f32_bytes && i8_bytes < bf16_bytes);
    }
}
