//! Dense host-side tensor substrate: f32 working precision, quantized
//! weight *views* for the frozen backbone.
//!
//! Activations, deltas, and all mutable state are f32 [`Tensor`]s. Frozen
//! backbone weights can additionally live at reduced precision — bf16
//! ([`bf16`], 2 B/elem, also the delta checkpoint codec) or int8 with
//! per-row scales ([`quant`], ~1 B/elem) — and are read through the
//! [`quant::MatRef`] view type (`F32` / `Bf16` / `I8`), so the NeuroAda
//! invariant (frozen base + full-precision sparse deltas, the QLoRA
//! pattern) is visible in the types: only `&[f32]` can be trained or
//! merged into; quantized data is read-only by construction.
//!
//! Every `A·Bᵀ` over a `MatRef` runs through the single [`ops::gemm_nt`]
//! dispatch point — one pooled entry ([`pool::KernelPool`], with
//! `KernelPool::serial()` for the poolless case), two loop orders
//! ([`ops::Kernel`]: cache-blocked default, scalar parity oracle), one
//! 4-wide dequantize-in-register dot kernel per dtype. Per-dtype resident
//! bytes for an `[n, k]` matrix: f32 `4·n·k`, bf16 `2·n·k`, int8
//! `n·k + 4·n` (data + scales).
//!
//! This is NOT a deep-learning framework: the heavy compute runs inside the
//! AOT HLO artifacts on PJRT. The host tensor exists for everything around
//! that — parameter initialization, selection, data generation, the pure-rust
//! reference transformer used in parity tests, serving on quantized
//! backbones, and metric computation.

pub mod bf16;
pub mod ops;
pub mod pool;
pub mod quant;

use crate::util::rng::Rng;

/// Row-major dense f32 tensor with up to 4 dims.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn ones(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![1.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn filled(shape: &[usize], v: f32) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![v; n] }
    }

    /// N(0, std²) init.
    pub fn randn(shape: &[usize], std: f32, rng: &mut Rng) -> Tensor {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(&mut t.data, std);
        t
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Size of dim `d`.
    pub fn dim(&self, d: usize) -> usize {
        self.shape[d]
    }

    pub fn reshape(mut self, shape: &[usize]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    /// 2-D accessors (the common case: weight matrices [d_out, d_in]).
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.rank(), 2);
        self.data[i * self.shape[1] + j]
    }

    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        debug_assert_eq!(self.rank(), 2);
        self.data[i * self.shape[1] + j] = v;
    }

    /// Row slice of a 2-D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert_eq!(self.rank(), 2);
        let w = self.shape[1];
        &self.data[i * w..(i + 1) * w]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        debug_assert_eq!(self.rank(), 2);
        let w = self.shape[1];
        &mut self.data[i * w..(i + 1) * w]
    }

    /// Element-wise in-place ops.
    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn scale(&mut self, s: f32) {
        for a in self.data.iter_mut() {
            *a *= s;
        }
    }

    /// Max |a - b| over all elements.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// L2 norm.
    pub fn norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Bytes if stored at the given dtype width (memory model helper).
    pub fn bytes(&self, dtype_bytes: usize) -> u64 {
        (self.numel() * dtype_bytes) as u64
    }
}

/// Integer tensor (token ids, selection indices).
#[derive(Debug, Clone, PartialEq)]
pub struct ITensor {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl ITensor {
    pub fn zeros(shape: &[usize]) -> ITensor {
        let n: usize = shape.iter().product();
        ITensor { shape: shape.to_vec(), data: vec![0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<i32>) -> ITensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        ITensor { shape: shape.to_vec(), data }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn at2(&self, i: usize, j: usize) -> i32 {
        self.data[i * self.shape[1] + j]
    }

    pub fn set2(&mut self, i: usize, j: usize, v: i32) {
        self.data[i * self.shape[1] + j] = v;
    }

    pub fn row(&self, i: usize) -> &[i32] {
        let w = self.shape[1];
        &self.data[i * w..(i + 1) * w]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_access() {
        let mut t = Tensor::zeros(&[3, 4]);
        t.set2(1, 2, 5.0);
        assert_eq!(t.at2(1, 2), 5.0);
        assert_eq!(t.row(1), &[0.0, 0.0, 5.0, 0.0]);
        assert_eq!(t.numel(), 12);
    }

    #[test]
    #[should_panic]
    fn from_vec_validates() {
        Tensor::from_vec(&[2, 2], vec![1.0]);
    }

    #[test]
    fn randn_is_seeded() {
        let mut r1 = Rng::new(1);
        let mut r2 = Rng::new(1);
        let a = Tensor::randn(&[8, 8], 0.5, &mut r1);
        let b = Tensor::randn(&[8, 8], 0.5, &mut r2);
        assert_eq!(a, b);
    }

    #[test]
    fn elementwise() {
        let mut a = Tensor::filled(&[2, 2], 1.0);
        let b = Tensor::filled(&[2, 2], 2.0);
        a.add_assign(&b);
        assert_eq!(a.data, vec![3.0; 4]);
        a.scale(0.5);
        assert_eq!(a.data, vec![1.5; 4]);
        assert!(a.max_abs_diff(&b) == 0.5);
    }
}
