//! Host-side numeric ops over [`Tensor`].
//!
//! Used by the reference transformer (parity tests vs the HLO artifacts),
//! selection, and evaluation. The hot training path does NOT run through
//! here — that's the AOT HLO on PJRT.

use super::pool::KernelPool;
use super::Tensor;

/// The shared dot kernel behind every `A·Bᵀ` variant: 4-wide manual unroll,
/// the autovectorizer does the rest. Serial and threaded matmuls both call
/// this per output element, so their results are bit-identical by
/// construction (same additions, same order).
#[inline]
fn nt_dot(ar: &[f32], br: &[f32], k: usize) -> f32 {
    let mut acc = 0.0f32;
    let mut t = 0;
    while t + 4 <= k {
        acc += ar[t] * br[t]
            + ar[t + 1] * br[t + 1]
            + ar[t + 2] * br[t + 2]
            + ar[t + 3] * br[t + 3];
        t += 4;
    }
    while t < k {
        acc += ar[t] * br[t];
        t += 1;
    }
    acc
}

/// One output row of `A·Bᵀ`: out[j] = a_row · b.row(j).
#[inline]
fn nt_row(ar: &[f32], b: &[f32], k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(ar.len(), k);
    debug_assert_eq!(out.len(), n);
    for (j, o) in out.iter_mut().enumerate() {
        *o = nt_dot(ar, &b[j * k..(j + 1) * k], k);
    }
}

/// Raw-slice `C = A·Bᵀ` with A [m, k], B [n, k] → out [m, n], row-partitioned
/// across the persistent [`KernelPool`]'s width.
///
/// Each output row is produced by the same serial kernel whichever executor
/// computes it, so any partition width yields bit-identical results — the
/// partition only divides rows, never a dot product. A serial pool (or a
/// single row) runs inline with zero dispatch overhead. This is the planned
/// forward's matmul: weights arrive as borrowed slices, never as copied
/// `Tensor`s, and the pool's workers are spawned once per server/bench/eval
/// rather than per call (see `tensor::pool`; the old per-call
/// scoped-spawn kernel survives as [`nt_into_scoped`], the bench baseline).
pub fn nt_into(
    a: &[f32],
    m: usize,
    k: usize,
    b: &[f32],
    n: usize,
    out: &mut [f32],
    pool: &KernelPool,
) {
    assert_eq!(a.len(), m * k, "A is [m, k]");
    assert_eq!(b.len(), n * k, "B is [n, k]");
    assert_eq!(out.len(), m * n, "out is [m, n]");
    if m == 0 || n == 0 {
        return;
    }
    let t = pool.threads().max(1).min(m);
    if t <= 1 {
        for (i, orow) in out.chunks_mut(n).enumerate() {
            nt_row(&a[i * k..(i + 1) * k], b, k, n, orow);
        }
        return;
    }
    let rows = m.div_ceil(t);
    pool.run_chunks(out, rows * n, |ci, chunk| {
        for (r, orow) in chunk.chunks_mut(n).enumerate() {
            let i = ci * rows + r;
            nt_row(&a[i * k..(i + 1) * k], b, k, n, orow);
        }
    });
}

/// PR 3's scoped-spawn kernel, kept verbatim as the perf baseline the
/// pooled [`nt_into`] is benchmarked against (`forward_bench`'s
/// pooled-vs-spawn cases) and cross-checked against bitwise in the parity
/// tests. Spawns `threads` OS threads per call — do not use on a hot path.
pub fn nt_into_scoped(
    a: &[f32],
    m: usize,
    k: usize,
    b: &[f32],
    n: usize,
    out: &mut [f32],
    threads: usize,
) {
    assert_eq!(a.len(), m * k, "A is [m, k]");
    assert_eq!(b.len(), n * k, "B is [n, k]");
    assert_eq!(out.len(), m * n, "out is [m, n]");
    if m == 0 || n == 0 {
        return;
    }
    let t = threads.max(1).min(m);
    if t <= 1 {
        for (i, orow) in out.chunks_mut(n).enumerate() {
            nt_row(&a[i * k..(i + 1) * k], b, k, n, orow);
        }
        return;
    }
    let rows = m.div_ceil(t);
    std::thread::scope(|s| {
        for (ci, chunk) in out.chunks_mut(rows * n).enumerate() {
            s.spawn(move || {
                for (r, orow) in chunk.chunks_mut(n).enumerate() {
                    let i = ci * rows + r;
                    nt_row(&a[i * k..(i + 1) * k], b, k, n, orow);
                }
            });
        }
    });
}

/// C = A·Bᵀ with A [m, k], B [n, k] → C [m, n], single-threaded.
///
/// The `b` operand is row-major [n, k], matching how weight matrices are
/// stored ([d_out, d_in]) so every row is a neuron and access is sequential.
pub fn matmul_nt(a: &Tensor, b: &Tensor) -> Tensor {
    matmul_nt_pooled(a, b, &KernelPool::serial())
}

/// C = A·Bᵀ row-partitioned across `pool`; bit-identical to
/// [`matmul_nt`] for every partition width (see [`nt_into`]).
pub fn matmul_nt_pooled(a: &Tensor, b: &Tensor, pool: &KernelPool) -> Tensor {
    assert_eq!(a.rank(), 2);
    assert_eq!(b.rank(), 2);
    let (m, k) = (a.shape[0], a.shape[1]);
    let (n, k2) = (b.shape[0], b.shape[1]);
    assert_eq!(k, k2, "inner dims: {:?} vs {:?}", a.shape, b.shape);
    let mut c = Tensor::zeros(&[m, n]);
    nt_into(&a.data, m, k, &b.data, n, &mut c.data, pool);
    c
}

/// C = A·B with A [m, k], B [k, n].
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.shape[1], b.shape[0]);
    let (m, k, n) = (a.shape[0], a.shape[1], b.shape[1]);
    let mut c = Tensor::zeros(&[m, n]);
    for i in 0..m {
        for t in 0..k {
            let av = a.data[i * k + t];
            if av == 0.0 {
                continue;
            }
            let brow = &b.data[t * n..(t + 1) * n];
            let crow = &mut c.data[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
    c
}

/// Row-wise softmax over the last dim of a 2-D tensor, in place.
pub fn softmax_rows(x: &mut Tensor) {
    assert_eq!(x.rank(), 2);
    let (m, n) = (x.shape[0], x.shape[1]);
    for i in 0..m {
        let row = &mut x.data[i * n..(i + 1) * n];
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0;
        for v in row.iter_mut() {
            *v = (*v - mx).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
}

/// RMSNorm over the last dim: x * rsqrt(mean(x²)+eps) * scale.
pub fn rmsnorm(x: &[f32], scale: &[f32], out: &mut [f32]) {
    let d = scale.len();
    debug_assert_eq!(x.len(), d);
    let ms = x.iter().map(|v| v * v).sum::<f32>() / d as f32;
    let r = 1.0 / (ms + 1e-6).sqrt();
    for i in 0..d {
        out[i] = x[i] * r * scale[i];
    }
}

/// SiLU activation.
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// log-softmax of a row, returning the log-prob of `target`.
pub fn log_softmax_pick(row: &[f32], target: usize) -> f32 {
    let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let lse = mx + row.iter().map(|v| (v - mx).exp()).sum::<f32>().ln();
    row[target] - lse
}

/// Sinusoidal positional encoding matching python model._positional:
/// concat(sin(ang), cos(ang)) with ang[p, i] = p / 10000^(2i/d).
pub fn positional(seq: usize, d: usize) -> Tensor {
    let mut t = Tensor::zeros(&[seq, d]);
    let half = d / 2;
    for p in 0..seq {
        for i in 0..half {
            let ang = p as f64 / (10000f64).powf(2.0 * i as f64 / d as f64);
            t.set2(p, i, ang.sin() as f32);
            t.set2(p, half + i, ang.cos() as f32);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_nt_small() {
        // A = [[1,2],[3,4]], B = [[1,0],[0,1],[1,1]] (rows are B's "neurons")
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::from_vec(&[3, 2], vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0]);
        let c = matmul_nt(&a, &b);
        assert_eq!(c.data, vec![1.0, 2.0, 3.0, 3.0, 4.0, 7.0]);
    }

    #[test]
    fn matmul_agrees_with_nt() {
        use crate::util::rng::Rng;
        let mut r = Rng::new(2);
        let a = Tensor::randn(&[5, 7], 1.0, &mut r);
        let b = Tensor::randn(&[4, 7], 1.0, &mut r);
        // A·Bᵀ via matmul on transposed copy
        let mut bt = Tensor::zeros(&[7, 4]);
        for i in 0..4 {
            for j in 0..7 {
                bt.set2(j, i, b.at2(i, j));
            }
        }
        let c1 = matmul_nt(&a, &b);
        let c2 = matmul(&a, &bt);
        assert!(c1.max_abs_diff(&c2) < 1e-5);
    }

    #[test]
    fn softmax_normalizes() {
        let mut x = Tensor::from_vec(&[2, 3], vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0]);
        softmax_rows(&mut x);
        for i in 0..2 {
            let s: f32 = x.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
        assert!(x.at2(0, 2) > x.at2(0, 1));
    }

    #[test]
    fn rmsnorm_unit_scale() {
        let x = [3.0f32, 4.0];
        let scale = [1.0f32, 1.0];
        let mut out = [0.0f32; 2];
        rmsnorm(&x, &scale, &mut out);
        let ms = (9.0 + 16.0) / 2.0;
        assert!((out[0] - 3.0 / (ms as f32).sqrt()).abs() < 1e-4);
    }

    #[test]
    fn log_softmax_sums() {
        let row = [1.0f32, 2.0, 3.0];
        let total: f32 = (0..3).map(|t| log_softmax_pick(&row, t).exp()).sum();
        assert!((total - 1.0).abs() < 1e-5);
    }

    #[test]
    fn pooled_matmul_is_bitwise_serial() {
        use crate::util::rng::Rng;
        let mut r = Rng::new(9);
        let pools: Vec<KernelPool> =
            [2usize, 3, 4, 32].iter().map(|&t| KernelPool::new(t)).collect();
        // odd shapes: m, n, k deliberately not multiples of the partition
        for (m, n, k) in [(1usize, 5usize, 3usize), (7, 11, 13), (17, 3, 9), (5, 1, 4)] {
            let a = Tensor::randn(&[m, k], 1.0, &mut r);
            let b = Tensor::randn(&[n, k], 1.0, &mut r);
            let serial = matmul_nt(&a, &b);
            for pool in &pools {
                let par = matmul_nt_pooled(&a, &b, pool);
                assert_eq!(serial.data, par.data, "m={m} n={n} k={k} t={}", pool.threads());
                // and the scoped-spawn baseline agrees with both
                let mut scoped = vec![0.0f32; m * n];
                nt_into_scoped(&a.data, m, k, &b.data, n, &mut scoped, pool.threads());
                assert_eq!(serial.data, scoped, "scoped m={m} n={n} k={k}");
            }
        }
    }

    #[test]
    fn nt_into_matches_tensor_path() {
        use crate::util::rng::Rng;
        let mut r = Rng::new(10);
        let a = Tensor::randn(&[6, 5], 1.0, &mut r);
        let b = Tensor::randn(&[4, 5], 1.0, &mut r);
        let c = matmul_nt(&a, &b);
        let mut out = vec![0.0f32; 6 * 4];
        nt_into(&a.data, 6, 5, &b.data, 4, &mut out, &KernelPool::new(2));
        assert_eq!(c.data, out);
    }
}
