//! Host-side numeric ops over [`Tensor`], and THE GEMM dispatch point.
//!
//! Every `A·Bᵀ` in the crate goes through [`gemm_nt`] (or the explicit
//! [`Kernel`] it dispatches to): A is always f32 activations `[m, k]`, B is
//! a [`MatRef`] weight view `[n, k]` in any backbone dtype (f32 / bf16 /
//! int8 with per-row scales), and the work is row-partitioned across a
//! persistent [`KernelPool`] (`KernelPool::serial()` covers the poolless
//! case). Two kernels compute identical results per dtype:
//!
//! * [`Kernel::Scalar`] — the straight row-major loop, kept as the parity
//!   oracle (this is the pre-redesign `nt_into` body for f32).
//! * [`Kernel::Blocked`] — the default: a cache-blocked loop reorder that
//!   walks B in [`B_PANEL`]-row panels so a panel stays L1-resident across
//!   all of a chunk's A rows, instead of streaming the whole of B once per
//!   row. Each output element is still produced by the *same* per-dtype dot
//!   kernel in the same order, so Blocked ≡ Scalar **bitwise** at any pool
//!   width — blocking reorders loop iterations, never additions.
//!
//! The per-dtype dots are 4-wide unrolled with dequantize-in-register for
//! bf16/int8 (`tensor::quant`); the f32 dot is [`nt_dot`], unchanged from
//! the pre-redesign kernels, so existing f32 parity tests stay bitwise.
//! Used by the reference transformer (parity tests vs the HLO artifacts),
//! selection, and evaluation. The hot training path does NOT run through
//! here — that's the AOT HLO on PJRT.

use super::pool::KernelPool;
use super::quant::{nt_dot_bf16, nt_dot_i8, MatRef};
use super::Tensor;

/// The shared f32 dot kernel behind every `A·Bᵀ` variant: 4-wide manual
/// unroll, the autovectorizer does the rest. Serial and threaded matmuls
/// both call this per output element, so their results are bit-identical by
/// construction (same additions, same order).
#[inline]
fn nt_dot(ar: &[f32], br: &[f32], k: usize) -> f32 {
    let mut acc = 0.0f32;
    let mut t = 0;
    while t + 4 <= k {
        acc += ar[t] * br[t]
            + ar[t + 1] * br[t + 1]
            + ar[t + 2] * br[t + 2]
            + ar[t + 3] * br[t + 3];
        t += 4;
    }
    while t < k {
        acc += ar[t] * br[t];
        t += 1;
    }
    acc
}

/// One output row of `A·Bᵀ`: out[j] = a_row · b.row(j). (The scoped-spawn
/// bench baseline's row kernel.)
#[inline]
fn nt_row(ar: &[f32], b: &[f32], k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(ar.len(), k);
    debug_assert_eq!(out.len(), n);
    for (j, o) in out.iter_mut().enumerate() {
        *o = nt_dot(ar, &b[j * k..(j + 1) * k], k);
    }
}

/// B-panel height of the blocked kernel: 64 rows × k columns of B reused
/// across every A row of a chunk (≤ 32 KiB of f32 panel at k = 128 — L1
/// territory; half/quarter that for bf16/int8).
const B_PANEL: usize = 64;

/// GEMM kernel choice. Both members compute identical results per dtype —
/// the same per-dtype dot per output element — so this is purely a loop
/// order / perf knob, benchmarked against each other in `forward_bench`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum Kernel {
    /// Cache-blocked panels over B (the default).
    #[default]
    Blocked,
    /// Straight row-major loop — the parity oracle and bench baseline.
    Scalar,
}

/// `C = A·Bᵀ` through the default kernel: A `[m, k]` f32 activations, B
/// `[n, k]` weights in any dtype, out `[m, n]`, row-partitioned across
/// `pool`. The single public GEMM entry point — every matmul call site in
/// the crate routes here.
///
/// Bit-exactness contract: results are identical for every pool width and
/// both [`Kernel`]s (the partition divides output rows, never a dot; the
/// kernels share one dot per dtype). The f32 dot is the pre-redesign
/// kernel, so f32 results are bitwise unchanged from the old `nt_into`.
pub fn gemm_nt(
    a: &[f32],
    m: usize,
    k: usize,
    b: MatRef<'_>,
    n: usize,
    out: &mut [f32],
    pool: &KernelPool,
) {
    Kernel::default().gemm_nt(a, m, k, b, n, out, pool)
}

impl Kernel {
    /// `C = A·Bᵀ` through this specific kernel (see [`gemm_nt`]).
    pub fn gemm_nt(
        self,
        a: &[f32],
        m: usize,
        k: usize,
        b: MatRef<'_>,
        n: usize,
        out: &mut [f32],
        pool: &KernelPool,
    ) {
        assert_eq!(a.len(), m * k, "A is [m, k]");
        assert_eq!(b.len(), n * k, "B is [n, k]");
        assert_eq!(out.len(), m * n, "out is [m, n]");
        if m == 0 || n == 0 {
            return;
        }
        let t = pool.threads().max(1).min(m);
        if t <= 1 {
            self.row_range(a, 0, k, b, n, out);
            return;
        }
        let rows = m.div_ceil(t);
        pool.run_chunks(out, rows * n, |ci, chunk| {
            self.row_range(a, ci * rows, k, b, n, chunk);
        });
    }

    /// Compute output rows `r0 ..` into `out` (`out.len() / n` rows).
    fn row_range(self, a: &[f32], r0: usize, k: usize, b: MatRef<'_>, n: usize, out: &mut [f32]) {
        match b {
            MatRef::F32(w) => {
                self.row_range_with(a, r0, k, n, out, |ar, j| nt_dot(ar, &w[j * k..(j + 1) * k], k))
            }
            MatRef::Bf16(w) => self.row_range_with(a, r0, k, n, out, |ar, j| {
                nt_dot_bf16(ar, &w[j * k..(j + 1) * k], k)
            }),
            MatRef::I8 { data, scales } => self.row_range_with(a, r0, k, n, out, |ar, j| {
                nt_dot_i8(ar, &data[j * k..(j + 1) * k], k, scales[j])
            }),
        }
    }

    /// The two loop orders over one monomorphized per-dtype dot.
    #[inline]
    fn row_range_with(
        self,
        a: &[f32],
        r0: usize,
        k: usize,
        n: usize,
        out: &mut [f32],
        dot: impl Fn(&[f32], usize) -> f32,
    ) {
        match self {
            Kernel::Scalar => {
                for (r, orow) in out.chunks_mut(n).enumerate() {
                    let ar = &a[(r0 + r) * k..(r0 + r + 1) * k];
                    for (j, o) in orow.iter_mut().enumerate() {
                        *o = dot(ar, j);
                    }
                }
            }
            Kernel::Blocked => {
                let rows = out.len() / n;
                let mut jb = 0;
                while jb < n {
                    let je = (jb + B_PANEL).min(n);
                    for r in 0..rows {
                        let ar = &a[(r0 + r) * k..(r0 + r + 1) * k];
                        for (dj, o) in out[r * n + jb..r * n + je].iter_mut().enumerate() {
                            *o = dot(ar, jb + dj);
                        }
                    }
                    jb = je;
                }
            }
        }
    }
}

/// PR 3's scoped-spawn kernel, kept crate-private purely as the perf
/// baseline the pooled [`gemm_nt`] is benchmarked against
/// (`forward_bench`'s pooled-vs-spawn cases). Spawns `threads` OS threads
/// per call — do not use on a hot path.
pub(crate) fn nt_into_scoped(
    a: &[f32],
    m: usize,
    k: usize,
    b: &[f32],
    n: usize,
    out: &mut [f32],
    threads: usize,
) {
    assert_eq!(a.len(), m * k, "A is [m, k]");
    assert_eq!(b.len(), n * k, "B is [n, k]");
    assert_eq!(out.len(), m * n, "out is [m, n]");
    if m == 0 || n == 0 {
        return;
    }
    let t = threads.max(1).min(m);
    if t <= 1 {
        for (i, orow) in out.chunks_mut(n).enumerate() {
            nt_row(&a[i * k..(i + 1) * k], b, k, n, orow);
        }
        return;
    }
    let rows = m.div_ceil(t);
    std::thread::scope(|s| {
        for (ci, chunk) in out.chunks_mut(rows * n).enumerate() {
            s.spawn(move || {
                for (r, orow) in chunk.chunks_mut(n).enumerate() {
                    let i = ci * rows + r;
                    nt_row(&a[i * k..(i + 1) * k], b, k, n, orow);
                }
            });
        }
    });
}

/// C = A·B with A [m, k], B [k, n].
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.shape[1], b.shape[0]);
    let (m, k, n) = (a.shape[0], a.shape[1], b.shape[1]);
    let mut c = Tensor::zeros(&[m, n]);
    for i in 0..m {
        for t in 0..k {
            let av = a.data[i * k + t];
            if av == 0.0 {
                continue;
            }
            let brow = &b.data[t * n..(t + 1) * n];
            let crow = &mut c.data[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
    c
}

/// Row-wise softmax over the last dim of a 2-D tensor, in place.
pub fn softmax_rows(x: &mut Tensor) {
    assert_eq!(x.rank(), 2);
    let (m, n) = (x.shape[0], x.shape[1]);
    for i in 0..m {
        let row = &mut x.data[i * n..(i + 1) * n];
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0;
        for v in row.iter_mut() {
            *v = (*v - mx).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
}

/// RMSNorm over the last dim: x * rsqrt(mean(x²)+eps) * scale.
pub fn rmsnorm(x: &[f32], scale: &[f32], out: &mut [f32]) {
    let d = scale.len();
    debug_assert_eq!(x.len(), d);
    let ms = x.iter().map(|v| v * v).sum::<f32>() / d as f32;
    let r = 1.0 / (ms + 1e-6).sqrt();
    for i in 0..d {
        out[i] = x[i] * r * scale[i];
    }
}

/// SiLU activation.
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// log-softmax of a row, returning the log-prob of `target`.
pub fn log_softmax_pick(row: &[f32], target: usize) -> f32 {
    let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let lse = mx + row.iter().map(|v| (v - mx).exp()).sum::<f32>().ln();
    row[target] - lse
}

/// Sinusoidal positional encoding matching python model._positional:
/// concat(sin(ang), cos(ang)) with ang[p, i] = p / 10000^(2i/d).
pub fn positional(seq: usize, d: usize) -> Tensor {
    let mut t = Tensor::zeros(&[seq, d]);
    let half = d / 2;
    for p in 0..seq {
        for i in 0..half {
            let ang = p as f64 / (10000f64).powf(2.0 * i as f64 / d as f64);
            t.set2(p, i, ang.sin() as f32);
            t.set2(p, half + i, ang.cos() as f32);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::quant::{BackboneDtype, QuantMat};

    /// Tensor-shaped wrapper over the dispatch, for test ergonomics.
    fn gemm(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = (a.shape[0], a.shape[1]);
        let n = b.shape[0];
        assert_eq!(k, b.shape[1]);
        let mut c = Tensor::zeros(&[m, n]);
        gemm_nt(&a.data, m, k, MatRef::F32(&b.data), n, &mut c.data, &KernelPool::serial());
        c
    }

    #[test]
    fn matmul_nt_small() {
        // A = [[1,2],[3,4]], B = [[1,0],[0,1],[1,1]] (rows are B's "neurons")
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::from_vec(&[3, 2], vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0]);
        let c = gemm(&a, &b);
        assert_eq!(c.data, vec![1.0, 2.0, 3.0, 3.0, 4.0, 7.0]);
    }

    #[test]
    fn matmul_agrees_with_nt() {
        use crate::util::rng::Rng;
        let mut r = Rng::new(2);
        let a = Tensor::randn(&[5, 7], 1.0, &mut r);
        let b = Tensor::randn(&[4, 7], 1.0, &mut r);
        // A·Bᵀ via matmul on transposed copy
        let mut bt = Tensor::zeros(&[7, 4]);
        for i in 0..4 {
            for j in 0..7 {
                bt.set2(j, i, b.at2(i, j));
            }
        }
        let c1 = gemm(&a, &b);
        let c2 = matmul(&a, &bt);
        assert!(c1.max_abs_diff(&c2) < 1e-5);
    }

    #[test]
    fn softmax_normalizes() {
        let mut x = Tensor::from_vec(&[2, 3], vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0]);
        softmax_rows(&mut x);
        for i in 0..2 {
            let s: f32 = x.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
        assert!(x.at2(0, 2) > x.at2(0, 1));
    }

    #[test]
    fn rmsnorm_unit_scale() {
        let x = [3.0f32, 4.0];
        let scale = [1.0f32, 1.0];
        let mut out = [0.0f32; 2];
        rmsnorm(&x, &scale, &mut out);
        let ms = (9.0 + 16.0) / 2.0;
        assert!((out[0] - 3.0 / (ms as f32).sqrt()).abs() < 1e-4);
    }

    #[test]
    fn log_softmax_sums() {
        let row = [1.0f32, 2.0, 3.0];
        let total: f32 = (0..3).map(|t| log_softmax_pick(&row, t).exp()).sum();
        assert!((total - 1.0).abs() < 1e-5);
    }

    /// The pool/kernel bitwise contract, every dtype: for each shape, the
    /// serial Scalar result is the oracle; Blocked, every pool width, and
    /// (f32) the scoped-spawn baseline must all equal it bitwise. bf16
    /// additionally equals the f32 kernel run on the exactly-dequantized
    /// matrix — dequantize-in-register changes no additions.
    #[test]
    fn pooled_matmul_is_bitwise_serial() {
        use crate::util::rng::Rng;
        let mut r = Rng::new(9);
        let pools: Vec<KernelPool> =
            [2usize, 3, 4, 32].iter().map(|&t| KernelPool::new(t)).collect();
        let serial = KernelPool::serial();
        // odd shapes: m, n, k deliberately not multiples of the partition
        // (and of the blocked panel); the last crosses B_PANEL
        for (m, n, k) in [(1usize, 5usize, 3usize), (7, 11, 13), (17, 3, 9), (5, 1, 4), (3, 130, 6)]
        {
            let a = Tensor::randn(&[m, k], 1.0, &mut r);
            let b = Tensor::randn(&[n, k], 1.0, &mut r);
            let mut want = vec![0.0f32; m * n];
            Kernel::Scalar.gemm_nt(&a.data, m, k, MatRef::F32(&b.data), n, &mut want, &serial);
            let mut got = vec![0.0f32; m * n];
            for pool in pools.iter().chain([&serial]) {
                for kern in [Kernel::Scalar, Kernel::Blocked] {
                    got.fill(0.0);
                    kern.gemm_nt(&a.data, m, k, MatRef::F32(&b.data), n, &mut got, pool);
                    assert_eq!(want, got, "{kern:?} m={m} n={n} k={k} t={}", pool.threads());
                }
            }
            // the scoped-spawn baseline agrees with all of them
            for pool in &pools {
                got.fill(0.0);
                nt_into_scoped(&a.data, m, k, &b.data, n, &mut got, pool.threads());
                assert_eq!(want, got, "scoped m={m} n={n} k={k}");
            }
            // quantized dtypes: Scalar ≡ Blocked ≡ pooled bitwise per dtype
            for dtype in [BackboneDtype::Bf16, BackboneDtype::I8] {
                let q = QuantMat::quantize(dtype, n, k, &b.data);
                let mut qwant = vec![0.0f32; m * n];
                Kernel::Scalar.gemm_nt(&a.data, m, k, q.as_ref(), n, &mut qwant, &serial);
                for pool in pools.iter().chain([&serial]) {
                    for kern in [Kernel::Scalar, Kernel::Blocked] {
                        got.fill(0.0);
                        kern.gemm_nt(&a.data, m, k, q.as_ref(), n, &mut got, pool);
                        assert_eq!(
                            qwant,
                            got,
                            "{} {kern:?} m={m} n={n} k={k} t={}",
                            dtype.name(),
                            pool.threads()
                        );
                    }
                }
                if dtype == BackboneDtype::Bf16 {
                    // bf16 dequant is exact, so in-register dequant equals
                    // the f32 kernel on the dequantized matrix BITWISE
                    let dq = q.dequant();
                    got.fill(0.0);
                    Kernel::Scalar.gemm_nt(&a.data, m, k, MatRef::F32(&dq), n, &mut got, &serial);
                    assert_eq!(qwant, got, "bf16 in-register vs dequantized m={m} n={n} k={k}");
                }
            }
        }
    }

    #[test]
    fn gemm_matches_tensor_path() {
        use crate::util::rng::Rng;
        let mut r = Rng::new(10);
        let a = Tensor::randn(&[6, 5], 1.0, &mut r);
        let b = Tensor::randn(&[4, 5], 1.0, &mut r);
        let c = gemm(&a, &b);
        let mut out = vec![0.0f32; 6 * 4];
        gemm_nt(&a.data, 6, 5, MatRef::F32(&b.data), 4, &mut out, &KernelPool::new(2));
        assert_eq!(c.data, out);
    }
}
