//! Persistent worker pool for data-parallel compute kernels.
//!
//! PR 3's row-partitioned matmuls spawned `std::thread::scope` threads per
//! batched matmul. That was correct and bit-identical to serial, but every
//! call paid OS thread creation + teardown (tens of microseconds), which is
//! why the single-row decode step stayed serial: per-token spawns would have
//! cost more than the O(d²) step they wrap. [`KernelPool`] removes that
//! excuse — workers are spawned ONCE, live as long as the pool, and pick up
//! per-call row-range tasks through an atomic cursor with a blocking join.
//!
//! Design:
//!
//! * **Partition width vs executors.** `threads` is the *partition* width —
//!   kernels split their output into up to `threads` row ranges, exactly as
//!   the scoped-spawn kernels did, so results stay bit-identical to serial
//!   regardless of how many executors exist. The pool spawns
//!   `min(threads, cores) - 1` persistent workers (the calling thread is
//!   always executor #0), so an oversized `--threads` never oversubscribes
//!   the machine — the dynamic task cursor load-balances the extra ranges.
//! * **One job at a time.** Concurrent callers (scheduler workers + the
//!   decode thread share one pool per `Server`) serialize on an internal
//!   turn lock: the machine's cores are one resource, and two kernels
//!   racing each other would just thrash. Each `run` is a blocking join —
//!   it returns only after every task of its job has executed, which is
//!   also what makes lending stack-borrowed closures to the workers sound.
//! * **Fast handoff.** Workers spin briefly on an epoch atomic before
//!   falling back to a condvar, so back-to-back kernels (the decode step
//!   issues ~7 jobs per layer per token) pay ~microsecond pickup, not a
//!   scheduler round trip.
//! * **Panic containment.** A panicking task poisons the job, the join
//!   still completes (no deadlocked `run`), and the *caller* re-panics.
//!   Workers survive to serve the next job.
//! * **Optional per-job timing.** [`KernelPool::set_timed`] turns on
//!   per-task clocks feeding two utilization aggregates:
//!   [`KernelPool::busy_frac`] (busy executor-time / available
//!   executor-time) and [`KernelPool::imbalance`] (slowest task × task
//!   count / total busy — 1.0 means a perfectly uniform partition). The
//!   untimed hot path pays exactly one extra relaxed atomic load per
//!   `run`; the serving stack enables timing alongside request tracing.
//!
//! Ownership: one pool per [`serve::Server`](crate::serve::Server) (sized by
//! `ServeCfg::threads` / `NEUROADA_THREADS` / `--threads`, shared by the
//! scheduler workers and the decode thread), one per bench or eval
//! invocation. `KernelPool` is a cheap `Arc` handle — a resolved
//! [`PlannedModel`](crate::model::PlannedModel) holds a clone, and the
//! workers shut down (joined) when the last handle drops.
//!
//! Tasks must not call back into the pool (the turn lock is not reentrant);
//! every kernel routed through here is a leaf computation.
//!
//! Sibling of [`coordinator::pool::Pool`](crate::coordinator::pool::Pool),
//! which fans out coarse *jobs* (experiments, sweep points) over a
//! spawn-per-scatter queue; `KernelPool` is for fine-grained *data-parallel*
//! kernels where dispatch latency dominates.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread;

/// A borrowed data-parallel task body: called once per task index.
type TaskFn = dyn Fn(usize) + Sync;

/// One published job: the lifetime-erased task plus its own cursor and
/// completion counter. The counters live *in the job* (not the pool) so a
/// straggling worker still draining a finished job can never steal indices
/// from the next one.
struct JobCtx {
    /// Erased borrow of the caller's closure — sound because `run` does not
    /// return until `remaining` hits zero and the slot is cleared.
    task: &'static TaskFn,
    n_tasks: usize,
    cursor: AtomicUsize,
    remaining: AtomicUsize,
    poisoned: AtomicBool,
    /// Snapshot of `Inner::timed` at publication: executors check a plain
    /// bool, not the shared atomic.
    timed: bool,
    /// Σ per-task durations (ns). Folded *before* each task's `remaining`
    /// decrement, so the joining caller (which observes the final
    /// decrement with Acquire) reads complete counters — no fold can race
    /// past the join.
    busy_ns: AtomicU64,
    /// Slowest single task (ns) — the imbalance numerator.
    max_task_ns: AtomicU64,
}

struct Slot {
    job: Option<Arc<JobCtx>>,
    epoch: u64,
    shutdown: bool,
}

struct Inner {
    /// Partition width kernels split their work into (NOT the executor
    /// count — see the module docs).
    threads: usize,
    /// Persistent workers spawned (`min(threads, cores) - 1`).
    workers: usize,
    slot: Mutex<Slot>,
    work_cv: Condvar,
    done_cv: Condvar,
    /// Mirrors `Slot::epoch` for the workers' lock-free spin fast path.
    epoch: AtomicU64,
    /// Serializes concurrent `run` callers (one job at a time).
    turn: Mutex<()>,
    jobs: AtomicU64,
    dispatched: AtomicU64,
    tasks: AtomicU64,
    /// Per-job timing gate — the ONLY cost the untimed path pays is one
    /// relaxed load of this per `run`.
    timed: AtomicBool,
    timed_jobs: AtomicU64,
    /// Σ busy executor nanoseconds over timed jobs.
    t_busy_ns: AtomicU64,
    /// Σ wall × executor-count nanoseconds over timed jobs (the busy
    /// fraction's denominator: time the executors *could* have worked).
    t_avail_ns: AtomicU64,
    /// Σ (slowest task × task count) nanoseconds over timed jobs; divided
    /// by `t_busy_ns` this is the busy-weighted task imbalance (≥ 1.0).
    t_maxw_ns: AtomicU64,
}

/// Spin iterations before a waiter falls back to its condvar. Roughly a few
/// microseconds — enough to catch the next kernel of a back-to-back stream,
/// short enough not to burn a core when the pool goes idle.
const SPIN: u32 = 1 << 14;

fn run_tasks(inner: &Inner, ctx: &JobCtx) {
    loop {
        let i = ctx.cursor.fetch_add(1, Ordering::Relaxed);
        if i >= ctx.n_tasks {
            return;
        }
        let task = ctx.task;
        let t0 = ctx.timed.then(std::time::Instant::now);
        if std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| task(i))).is_err() {
            ctx.poisoned.store(true, Ordering::Release);
        }
        if let Some(t0) = t0 {
            let d = t0.elapsed().as_nanos() as u64;
            ctx.busy_ns.fetch_add(d, Ordering::Relaxed);
            ctx.max_task_ns.fetch_max(d, Ordering::Relaxed);
        }
        inner.tasks.fetch_add(1, Ordering::Relaxed);
        if ctx.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            // last task of the job: wake the joining caller. Taking the
            // slot lock orders the notify after the caller's wait, so the
            // wakeup can never be missed.
            let _g = inner.slot.lock().unwrap();
            inner.done_cv.notify_all();
        }
    }
}

fn worker_loop(inner: &Inner) {
    let mut seen = 0u64;
    loop {
        // fast path: spin for the next epoch before sleeping
        let mut spun = 0u32;
        while inner.epoch.load(Ordering::Acquire) == seen && spun < SPIN {
            std::hint::spin_loop();
            spun += 1;
        }
        let ctx = {
            let mut g = inner.slot.lock().unwrap();
            loop {
                if g.shutdown {
                    return;
                }
                if g.epoch != seen {
                    if let Some(ctx) = &g.job {
                        seen = g.epoch;
                        break ctx.clone();
                    }
                    // the job we spun towards already completed; wait for
                    // the next publication
                    seen = g.epoch;
                }
                g = inner.work_cv.wait(g).unwrap();
            }
        };
        run_tasks(inner, &ctx);
    }
}

/// Joins the workers when the last user handle drops. Workers hold
/// `Arc<Inner>` themselves, so shutdown is signalled by this guard rather
/// than by `Inner`'s refcount.
struct Guard {
    inner: Arc<Inner>,
    handles: Mutex<Vec<thread::JoinHandle<()>>>,
}

impl Drop for Guard {
    fn drop(&mut self) {
        {
            let mut g = self.inner.slot.lock().unwrap();
            g.shutdown = true;
        }
        self.inner.work_cv.notify_all();
        for h in self.handles.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

/// Long-lived, work-distributing pool for compute kernels. Cheap to clone
/// (an `Arc` handle); see the module docs for the execution model.
#[derive(Clone)]
pub struct KernelPool {
    inner: Arc<Inner>,
    _guard: Arc<Guard>,
}

impl KernelPool {
    /// Pool with partition width `threads` (clamped to >= 1). Spawns
    /// `min(threads, available cores) - 1` persistent workers; `threads <= 1`
    /// spawns none and every `run` executes inline (the serial baseline).
    pub fn new(threads: usize) -> KernelPool {
        let threads = threads.max(1);
        let cores = thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let workers = threads.min(cores).saturating_sub(1);
        let inner = Arc::new(Inner {
            threads,
            workers,
            slot: Mutex::new(Slot { job: None, epoch: 0, shutdown: false }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            epoch: AtomicU64::new(0),
            turn: Mutex::new(()),
            jobs: AtomicU64::new(0),
            dispatched: AtomicU64::new(0),
            tasks: AtomicU64::new(0),
            timed: AtomicBool::new(false),
            timed_jobs: AtomicU64::new(0),
            t_busy_ns: AtomicU64::new(0),
            t_avail_ns: AtomicU64::new(0),
            t_maxw_ns: AtomicU64::new(0),
        });
        let handles = (0..workers)
            .map(|i| {
                let inner = inner.clone();
                thread::Builder::new()
                    .name(format!("neuroada-kernel-{i}"))
                    .spawn(move || worker_loop(&inner))
                    .expect("spawn kernel pool worker")
            })
            .collect();
        let guard = Guard { inner: inner.clone(), handles: Mutex::new(handles) };
        KernelPool { inner, _guard: Arc::new(guard) }
    }

    /// The shared serial pool (partition width 1, no workers, `run` always
    /// inline). The bit-identical baseline every pooled kernel is tested
    /// against; also what `RefModel::plan` and the serial bench cells use.
    pub fn serial() -> KernelPool {
        static SERIAL: OnceLock<KernelPool> = OnceLock::new();
        SERIAL.get_or_init(|| KernelPool::new(1)).clone()
    }

    /// Partition width kernels split their work into.
    pub fn threads(&self) -> usize {
        self.inner.threads
    }

    /// Persistent workers spawned at construction (never changes — the
    /// pool-reuse tests assert on this).
    pub fn workers(&self) -> usize {
        self.inner.workers
    }

    /// Total `run` calls (inline and dispatched).
    pub fn jobs(&self) -> u64 {
        self.inner.jobs.load(Ordering::Relaxed)
    }

    /// `run` calls that actually engaged the workers.
    pub fn dispatched(&self) -> u64 {
        self.inner.dispatched.load(Ordering::Relaxed)
    }

    /// Total task bodies executed across all jobs.
    pub fn tasks(&self) -> u64 {
        self.inner.tasks.load(Ordering::Relaxed)
    }

    /// Enable/disable per-job timing (see the module docs). Off by
    /// default; the serving engine switches it on with request tracing.
    pub fn set_timed(&self, on: bool) {
        self.inner.timed.store(on, Ordering::Relaxed);
    }

    pub fn timed(&self) -> bool {
        self.inner.timed.load(Ordering::Relaxed)
    }

    /// Jobs that ran with timing enabled.
    pub fn timed_jobs(&self) -> u64 {
        self.inner.timed_jobs.load(Ordering::Relaxed)
    }

    /// Busy executor-time / available executor-time over timed jobs
    /// (in (0, 1]; the gap is dispatch latency + cursor contention +
    /// straggler waits). `None` until a timed job ran.
    pub fn busy_frac(&self) -> Option<f64> {
        let avail = self.inner.t_avail_ns.load(Ordering::Relaxed);
        if avail == 0 {
            return None;
        }
        Some(self.inner.t_busy_ns.load(Ordering::Relaxed) as f64 / avail as f64)
    }

    /// Busy-weighted task imbalance over timed jobs: slowest task ×
    /// task count / total busy, per job. Exactly 1.0 means every task of
    /// every job took the same time; 2.0 means the critical path is twice
    /// the mean. `None` until a timed job did measurable work.
    pub fn imbalance(&self) -> Option<f64> {
        let busy = self.inner.t_busy_ns.load(Ordering::Relaxed);
        if busy == 0 {
            return None;
        }
        Some(self.inner.t_maxw_ns.load(Ordering::Relaxed) as f64 / busy as f64)
    }

    /// Fold one timed job into the aggregates.
    fn fold_timing(&self, wall_ns: u64, busy_ns: u64, max_task_ns: u64, n_tasks: u64, execs: u64) {
        self.inner.timed_jobs.fetch_add(1, Ordering::Relaxed);
        self.inner.t_busy_ns.fetch_add(busy_ns, Ordering::Relaxed);
        self.inner.t_avail_ns.fetch_add(wall_ns.max(1) * execs, Ordering::Relaxed);
        self.inner.t_maxw_ns.fetch_add(max_task_ns * n_tasks, Ordering::Relaxed);
    }

    /// Execute `task(0..n_tasks)` across the pool and block until every
    /// task has run (the join). Tasks are claimed dynamically, so any
    /// executor may run any index — callers must make tasks independent
    /// (the kernels here write disjoint output ranges). Runs inline when
    /// the pool is serial, the job is a single task, or no workers exist.
    ///
    /// Panics (after completing the join) if any task panicked.
    pub fn run(&self, n_tasks: usize, task: &TaskFn) {
        self.inner.jobs.fetch_add(1, Ordering::Relaxed);
        let timed = self.inner.timed.load(Ordering::Relaxed);
        if self.inner.workers == 0 || n_tasks <= 1 {
            if timed {
                let t_wall = std::time::Instant::now();
                let mut busy = 0u64;
                let mut max_task = 0u64;
                for i in 0..n_tasks {
                    let t = std::time::Instant::now();
                    task(i);
                    let d = t.elapsed().as_nanos() as u64;
                    busy += d;
                    max_task = max_task.max(d);
                }
                let wall = t_wall.elapsed().as_nanos() as u64;
                self.inner.tasks.fetch_add(n_tasks as u64, Ordering::Relaxed);
                self.fold_timing(wall, busy, max_task, n_tasks as u64, 1);
            } else {
                for i in 0..n_tasks {
                    task(i);
                }
                self.inner.tasks.fetch_add(n_tasks as u64, Ordering::Relaxed);
            }
            return;
        }
        // one job at a time; a poisoned turn (a previous caller's task
        // panicked) must not wedge the pool for everyone else
        let turn = self.inner.turn.lock().unwrap_or_else(|e| e.into_inner());
        self.inner.dispatched.fetch_add(1, Ordering::Relaxed);
        let t_wall = timed.then(std::time::Instant::now);
        // Lifetime erasure: sound because this function does not return
        // until `remaining == 0` and the slot is cleared, so no
        // worker can touch `task` after the borrow ends.
        let task: &'static TaskFn = unsafe { &*(task as *const TaskFn) };
        let ctx = Arc::new(JobCtx {
            task,
            n_tasks,
            cursor: AtomicUsize::new(0),
            remaining: AtomicUsize::new(n_tasks),
            poisoned: AtomicBool::new(false),
            timed,
            busy_ns: AtomicU64::new(0),
            max_task_ns: AtomicU64::new(0),
        });
        {
            let mut g = self.inner.slot.lock().unwrap();
            g.epoch += 1;
            g.job = Some(ctx.clone());
            self.inner.epoch.store(g.epoch, Ordering::Release);
        }
        self.inner.work_cv.notify_all();
        // the caller is executor #0
        run_tasks(&self.inner, &ctx);
        // join: spin briefly for stragglers, then block on the condvar
        let mut spun = 0u32;
        while ctx.remaining.load(Ordering::Acquire) != 0 {
            if spun < SPIN {
                std::hint::spin_loop();
                spun += 1;
            } else {
                let mut g = self.inner.slot.lock().unwrap();
                while ctx.remaining.load(Ordering::Acquire) != 0 {
                    g = self.inner.done_cv.wait(g).unwrap();
                }
                break;
            }
        }
        {
            let mut g = self.inner.slot.lock().unwrap();
            g.job = None;
        }
        if let Some(t0) = t_wall {
            // the join (Acquire on the final `remaining` decrement)
            // ordered every per-task fold before this read
            self.fold_timing(
                t0.elapsed().as_nanos() as u64,
                ctx.busy_ns.load(Ordering::Acquire),
                ctx.max_task_ns.load(Ordering::Relaxed),
                n_tasks as u64,
                self.inner.workers as u64 + 1,
            );
        }
        drop(turn);
        if ctx.poisoned.load(Ordering::Acquire) {
            panic!("kernel pool task panicked");
        }
    }

    /// Partition `out` into consecutive `chunk_len`-element chunks and run
    /// `f(chunk_index, chunk)` for each across the pool. Chunks are
    /// disjoint, so each task owns its slice exclusively — this is the
    /// shape every pooled kernel uses (row ranges of a row-major output).
    pub fn run_chunks<T: Send, F: Fn(usize, &mut [T]) + Sync>(
        &self,
        out: &mut [T],
        chunk_len: usize,
        f: F,
    ) {
        if out.is_empty() {
            return;
        }
        assert!(chunk_len > 0, "run_chunks needs chunk_len >= 1");
        let len = out.len();
        let n_tasks = len.div_ceil(chunk_len);
        let base = SendPtr(out.as_mut_ptr());
        self.run(n_tasks, &|i| {
            let start = i * chunk_len;
            let end = (start + chunk_len).min(len);
            // SAFETY: chunks [start, end) are disjoint per task index, each
            // index runs exactly once per job, and `run` joins before the
            // `out` borrow ends.
            let chunk = unsafe { std::slice::from_raw_parts_mut(base.0.add(start), end - start) };
            f(i, chunk);
        });
    }
}

/// Raw base pointer of a mutable slice, smuggled into `Sync` closures for
/// disjoint-chunk writes (see [`KernelPool::run_chunks`] for the safety
/// argument).
struct SendPtr<T>(*mut T);

unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_every_task_exactly_once() {
        let pool = KernelPool::new(4);
        for n in [1usize, 2, 3, 7, 64, 1000] {
            let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            pool.run(n, &|i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "n={n}: every task must run exactly once"
            );
        }
    }

    #[test]
    fn run_chunks_partitions_disjointly() {
        let pool = KernelPool::new(3);
        // odd length vs chunk size: the tail chunk is short
        let mut out = vec![0usize; 17];
        pool.run_chunks(&mut out, 5, |ci, chunk| {
            for (r, v) in chunk.iter_mut().enumerate() {
                *v = ci * 5 + r + 1; // global index + 1
            }
        });
        let want: Vec<usize> = (1..=17).collect();
        assert_eq!(out, want);
        // empty output is a no-op
        pool.run_chunks(&mut [] as &mut [usize], 5, |_, _| panic!("no tasks"));
    }

    #[test]
    fn serial_pool_is_inline_and_counts() {
        // the shared serial() pool is inline by construction
        assert_eq!(KernelPool::serial().threads(), 1);
        assert_eq!(KernelPool::serial().workers(), 0);
        // counter assertions use a PRIVATE width-1 pool: the shared static
        // is used by concurrently-running tests, so its counters race
        let pool = KernelPool::new(1);
        assert_eq!((pool.threads(), pool.workers()), (1, 0));
        let sum = AtomicUsize::new(0);
        pool.run(8, &|i| {
            sum.fetch_add(i, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 28);
        assert_eq!(pool.jobs(), 1);
        assert_eq!(pool.tasks(), 8);
        assert_eq!(pool.dispatched(), 0, "a width-1 pool never dispatches");
    }

    #[test]
    fn pool_is_reusable_and_workers_are_stable() {
        let pool = KernelPool::new(3);
        let workers = pool.workers();
        assert!(workers <= 2, "never more workers than threads - 1");
        let (j0, t0) = (pool.jobs(), pool.tasks());
        for round in 1..=5u64 {
            let sum = AtomicUsize::new(0);
            pool.run(6, &|i| {
                sum.fetch_add(i + 1, Ordering::Relaxed);
            });
            assert_eq!(sum.load(Ordering::Relaxed), 21);
            assert_eq!(pool.jobs(), j0 + round);
            assert_eq!(pool.tasks(), t0 + 6 * round);
            // reuse spawns nothing: the worker set is fixed at construction
            assert_eq!(pool.workers(), workers);
        }
    }

    #[test]
    fn concurrent_callers_serialize_but_complete() {
        let pool = KernelPool::new(2);
        let total = Arc::new(AtomicUsize::new(0));
        thread::scope(|s| {
            for _ in 0..4 {
                let pool = pool.clone();
                let total = total.clone();
                s.spawn(move || {
                    for _ in 0..50 {
                        pool.run(4, &|_| {
                            total.fetch_add(1, Ordering::Relaxed);
                        });
                    }
                });
            }
        });
        assert_eq!(total.load(Ordering::Relaxed), 4 * 50 * 4);
    }

    #[test]
    fn task_panic_propagates_and_pool_survives() {
        let pool = KernelPool::new(2);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(4, &|i| {
                if i == 2 {
                    panic!("boom");
                }
            });
        }));
        assert!(caught.is_err(), "a panicking task must fail the run");
        // the pool is still serviceable afterwards
        let ok = AtomicUsize::new(0);
        pool.run(4, &|_| {
            ok.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(ok.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn clones_share_one_worker_set() {
        let pool = KernelPool::new(4);
        let clone = pool.clone();
        let before = pool.jobs();
        clone.run(2, &|_| {});
        assert_eq!(pool.jobs(), before + 1, "clones share counters (same pool)");
        assert_eq!(pool.workers(), clone.workers());
    }

    #[test]
    fn untimed_pool_reports_no_utilization() {
        let pool = KernelPool::new(2);
        pool.run(8, &|_| {});
        assert!(!pool.timed());
        assert_eq!(pool.timed_jobs(), 0);
        assert!(pool.busy_frac().is_none());
        assert!(pool.imbalance().is_none());
    }

    #[test]
    fn timed_jobs_record_busy_fraction_and_imbalance() {
        // dispatched path
        let pool = KernelPool::new(4);
        pool.set_timed(true);
        let (j0, t0) = (pool.jobs(), pool.tasks());
        for _ in 0..3 {
            pool.run(16, &|i| {
                // skewed tasks: index 0 is the straggler
                let spins = if i == 0 { 20_000 } else { 500 };
                let mut acc = 0u64;
                for k in 0..spins {
                    acc = acc.wrapping_add(std::hint::black_box(k));
                }
                std::hint::black_box(acc);
            });
        }
        assert_eq!(pool.timed_jobs(), 3);
        // timing is additive: the existing counters are untouched by it
        assert_eq!(pool.jobs(), j0 + 3);
        assert_eq!(pool.tasks(), t0 + 48);
        let busy = pool.busy_frac().expect("timed jobs ran");
        assert!(busy > 0.0 && busy <= 1.0, "busy fraction in (0,1], got {busy}");
        let imb = pool.imbalance().expect("timed jobs did work");
        assert!(imb >= 1.0, "imbalance is >= 1 by construction, got {imb}");
        // once disabled, the aggregates freeze
        pool.set_timed(false);
        let frozen = pool.timed_jobs();
        pool.run(16, &|_| {});
        assert_eq!(pool.timed_jobs(), frozen);
    }

    #[test]
    fn inline_timed_jobs_fold_too() {
        let pool = KernelPool::new(1); // width-1: always inline
        pool.set_timed(true);
        pool.run(4, &|i| {
            std::hint::black_box(i);
        });
        assert_eq!(pool.timed_jobs(), 1);
        assert_eq!(pool.dispatched(), 0, "inline jobs never dispatch");
        let busy = pool.busy_frac().unwrap();
        assert!(busy > 0.0 && busy <= 1.0);
        assert!(pool.imbalance().unwrap() >= 1.0);
    }
}
