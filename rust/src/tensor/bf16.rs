//! bfloat16 codec.
//!
//! The paper stores deltas in BF16 (§3.3 "all delta parameters are stored
//! directly in BF16 and no FP32 master weights are needed"). On the CPU-PJRT
//! substrate we *compute* in f32 (DESIGN.md §3), but the delta store and the
//! memory model use real BF16 packing so the byte accounting in Table 1 /
//! Eq. 5–6 is exact, and checkpoints are half the size.

/// Round-to-nearest-even f32 → bf16.
pub fn to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        // quiet NaN, preserving sign
        return ((bits >> 16) as u16) | 0x0040;
    }
    let lsb = (bits >> 16) & 1;
    let rounded = bits.wrapping_add(0x0000_7fff + lsb);
    (rounded >> 16) as u16
}

/// bf16 → f32 (exact).
pub fn to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// Pack a f32 slice to bf16.
pub fn pack(xs: &[f32]) -> Vec<u16> {
    xs.iter().map(|&x| to_bf16(x)).collect()
}

/// Unpack bf16 to f32.
pub fn unpack(hs: &[u16]) -> Vec<f32> {
    hs.iter().map(|&h| to_f32(h)).collect()
}

/// Max relative quantization error of bf16 (2^-8 mantissa step).
pub const BF16_EPS: f32 = 1.0 / 256.0;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_values_roundtrip() {
        for v in [0.0f32, 1.0, -1.0, 0.5, 2.0, -0.25, 1024.0] {
            assert_eq!(to_f32(to_bf16(v)), v, "{v}");
        }
    }

    #[test]
    fn rounding_error_bounded() {
        let mut x = 0.001f32;
        while x < 100.0 {
            let r = to_f32(to_bf16(x));
            assert!(((r - x) / x).abs() <= BF16_EPS, "{x} -> {r}");
            x *= 1.37;
        }
    }

    #[test]
    fn round_to_nearest_even() {
        // 1.0 + 2^-9 is exactly between bf16(1.0) and the next value; RNE
        // must round to the even mantissa (1.0).
        let x = 1.0f32 + 1.0 / 512.0;
        assert_eq!(to_f32(to_bf16(x)), 1.0);
        // 1.0 + 3·2^-9 rounds up to 1.0 + 2^-7... the next-next repr.
        let y = 1.0f32 + 3.0 / 512.0;
        assert_eq!(to_f32(to_bf16(y)), 1.0 + 1.0 / 128.0);
    }

    #[test]
    fn round_to_nearest_even_more_ties() {
        // bf16 step in [1,2) is 2^-7, so ties sit at odd multiples of
        // 2^-8. 1 + 2^-8 ties between 1.0 (mantissa lsb even) and
        // 1 + 2^-7 (odd) — RNE keeps the even 1.0.
        assert_eq!(to_f32(to_bf16(1.0 + 1.0 / 256.0)), 1.0);
        // 1 + 3·2^-8 ties between 1 + 2^-7 (odd) and 1 + 2^-6 (even) —
        // RNE rounds UP to the even neighbor this time.
        let up = 1.0f32 + 3.0 / 256.0;
        assert_eq!(to_f32(to_bf16(up)), 1.0 + 1.0 / 64.0);
        // Same tie on the negative side: magnitude rounds identically.
        assert_eq!(to_f32(to_bf16(-up)), -(1.0 + 1.0 / 64.0));
        // Next binade [2,4): step 2^-6, tie at 2 + 2^-7 → even 2.0 ...
        let tie2 = 2.0f32 + 1.0 / 128.0;
        assert_eq!(to_f32(to_bf16(tie2)), 2.0);
        // ... and one f32 ulp past the tie must round up.
        let past = f32::from_bits(tie2.to_bits() + 1);
        assert_eq!(to_f32(to_bf16(past)), 2.0 + 1.0 / 64.0);
    }

    #[test]
    fn subnormals_and_signed_zero() {
        // Signed zeros survive exactly.
        assert_eq!(to_bf16(0.0), 0x0000);
        assert_eq!(to_bf16(-0.0), 0x8000);
        assert!(to_f32(to_bf16(-0.0)).is_sign_negative());
        // The smallest positive f32 subnormal rounds to (signed) zero...
        let tiny = f32::from_bits(1);
        assert_eq!(to_f32(to_bf16(tiny)), 0.0);
        assert!(to_f32(to_bf16(-tiny)).is_sign_negative());
        // ...while a bf16-representable subnormal round-trips exactly
        // (exponent 0, mantissa bits within the top 7).
        let sub = f32::from_bits(0x0040_0000); // 2^-127
        assert_eq!(to_f32(to_bf16(sub)), sub);
        // Smallest normal stays normal.
        assert_eq!(to_f32(to_bf16(f32::MIN_POSITIVE)), f32::MIN_POSITIVE);
    }

    #[test]
    fn overflow_rounds_to_infinity() {
        // f32::MAX (0x7f7f_ffff) rounds up past the largest finite bf16
        // into the infinity encoding — RNE overflow behavior.
        assert_eq!(to_f32(to_bf16(f32::MAX)), f32::INFINITY);
        assert_eq!(to_f32(to_bf16(f32::MIN)), f32::NEG_INFINITY);
        // The largest f32 that is exactly a bf16 value stays finite.
        let max_bf16 = f32::from_bits(0x7f7f_0000);
        assert_eq!(to_f32(to_bf16(max_bf16)), max_bf16);
    }

    #[test]
    fn specials() {
        assert!(to_f32(to_bf16(f32::NAN)).is_nan());
        assert_eq!(to_f32(to_bf16(f32::INFINITY)), f32::INFINITY);
        assert_eq!(to_f32(to_bf16(f32::NEG_INFINITY)), f32::NEG_INFINITY);
    }

    #[test]
    fn pack_unpack() {
        let xs = [0.1f32, -2.7, 3.14159, 1e-3];
        let back = unpack(&pack(&xs));
        for (a, b) in xs.iter().zip(&back) {
            assert!(((a - b) / a).abs() <= BF16_EPS);
        }
    }
}
