//! bfloat16 codec.
//!
//! The paper stores deltas in BF16 (§3.3 "all delta parameters are stored
//! directly in BF16 and no FP32 master weights are needed"). On the CPU-PJRT
//! substrate we *compute* in f32 (DESIGN.md §3), but the delta store and the
//! memory model use real BF16 packing so the byte accounting in Table 1 /
//! Eq. 5–6 is exact, and checkpoints are half the size.

/// Round-to-nearest-even f32 → bf16.
pub fn to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        // quiet NaN, preserving sign
        return ((bits >> 16) as u16) | 0x0040;
    }
    let round_bit = 0x0000_8000u32;
    let lsb = (bits >> 16) & 1;
    let rounded = bits.wrapping_add(0x0000_7fff + lsb);
    let _ = round_bit;
    (rounded >> 16) as u16
}

/// bf16 → f32 (exact).
pub fn to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// Pack a f32 slice to bf16.
pub fn pack(xs: &[f32]) -> Vec<u16> {
    xs.iter().map(|&x| to_bf16(x)).collect()
}

/// Unpack bf16 to f32.
pub fn unpack(hs: &[u16]) -> Vec<f32> {
    hs.iter().map(|&h| to_f32(h)).collect()
}

/// Max relative quantization error of bf16 (2^-8 mantissa step).
pub const BF16_EPS: f32 = 1.0 / 256.0;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_values_roundtrip() {
        for v in [0.0f32, 1.0, -1.0, 0.5, 2.0, -0.25, 1024.0] {
            assert_eq!(to_f32(to_bf16(v)), v, "{v}");
        }
    }

    #[test]
    fn rounding_error_bounded() {
        let mut x = 0.001f32;
        while x < 100.0 {
            let r = to_f32(to_bf16(x));
            assert!(((r - x) / x).abs() <= BF16_EPS, "{x} -> {r}");
            x *= 1.37;
        }
    }

    #[test]
    fn round_to_nearest_even() {
        // 1.0 + 2^-9 is exactly between bf16(1.0) and the next value; RNE
        // must round to the even mantissa (1.0).
        let x = 1.0f32 + 1.0 / 512.0;
        assert_eq!(to_f32(to_bf16(x)), 1.0);
        // 1.0 + 3·2^-9 rounds up to 1.0 + 2^-7... the next-next repr.
        let y = 1.0f32 + 3.0 / 512.0;
        assert_eq!(to_f32(to_bf16(y)), 1.0 + 1.0 / 128.0);
    }

    #[test]
    fn specials() {
        assert!(to_f32(to_bf16(f32::NAN)).is_nan());
        assert_eq!(to_f32(to_bf16(f32::INFINITY)), f32::INFINITY);
        assert_eq!(to_f32(to_bf16(f32::NEG_INFINITY)), f32::NEG_INFINITY);
    }

    #[test]
    fn pack_unpack() {
        let xs = [0.1f32, -2.7, 3.14159, 1e-3];
        let back = unpack(&pack(&xs));
        for (a, b) in xs.iter().zip(&back) {
            assert!(((a - b) / a).abs() <= BF16_EPS);
        }
    }
}
