//! Model-size presets. These MUST mirror `python/compile/model.py::SIZES` —
//! the artifact manifest carries the authoritative copy and
//! `runtime::artifacts` asserts agreement when loading, so drift fails fast.

use super::ModelCfg;

/// Look up a preset by name.
pub fn model(name: &str) -> Option<ModelCfg> {
    let m = |name: &str, vocab, d_model, n_layers, n_heads, d_ff, seq, batch, causal, n_classes| ModelCfg {
        name: name.to_string(),
        vocab,
        d_model,
        n_layers,
        n_heads,
        d_ff,
        seq,
        batch,
        causal,
        n_classes,
    };
    Some(match name {
        "nano" => m("nano", 256, 64, 2, 4, 256, 32, 16, true, 0),
        "micro" => m("micro", 512, 128, 4, 4, 512, 48, 8, true, 0),
        "small" => m("small", 1024, 256, 6, 8, 1024, 64, 8, true, 0),
        "base" => m("base", 2048, 512, 8, 8, 2048, 64, 4, true, 0),
        "large" => m("large", 4096, 768, 12, 12, 3072, 64, 2, true, 0),
        "enc-micro" => m("enc-micro", 512, 128, 4, 4, 512, 48, 16, false, 5),
        _ => return None,
    })
}

/// The sizes Figure 5 sweeps (its x-axis: RoBERTa-base → LLaMA3-8B analog).
pub fn fig5_sizes() -> Vec<&'static str> {
    vec!["nano", "micro", "small", "base"]
}

/// All decoder sizes with artifacts in the default set.
pub fn decoder_sizes() -> Vec<&'static str> {
    vec!["nano", "micro", "small", "base"]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_exist() {
        for s in ["nano", "micro", "small", "base", "large", "enc-micro"] {
            let m = model(s).unwrap();
            assert_eq!(m.name, s);
            assert_eq!(m.d_model % m.n_heads, 0);
        }
        assert!(model("huge").is_none());
    }

    #[test]
    fn encoder_flags() {
        let e = model("enc-micro").unwrap();
        assert!(!e.causal);
        assert_eq!(e.n_classes, 5);
    }

    #[test]
    fn backbone_counts_are_increasing() {
        let sizes = ["nano", "micro", "small", "base", "large"];
        let counts: Vec<u64> = sizes.iter().map(|s| model(s).unwrap().backbone_params()).collect();
        assert!(counts.windows(2).all(|w| w[0] < w[1]), "{counts:?}");
    }
}
