//! Config system: a TOML-subset parser plus the typed experiment configs
//! every CLI subcommand and experiment driver consumes.
//!
//! Supported TOML subset: `[section]` and `[section.sub]` headers, `key =
//! value` with string / int / float / bool / flat arrays, `#` comments.
//! That covers every config this project ships (configs/*.toml); the parser
//! rejects anything outside the subset loudly rather than mis-reading it.

pub mod presets;
pub mod toml;

use crate::peft::MethodKind;
use std::collections::BTreeMap;

pub use toml::{parse_toml, TomlValue};

/// Model architecture — must mirror python `compile/model.py::SIZES` (the
/// manifest carries the authoritative copy per artifact; `runtime` verifies
/// agreement at load).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelCfg {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq: usize,
    pub batch: usize,
    pub causal: bool,
    pub n_classes: usize,
}

impl ModelCfg {
    /// Every adapted projection, name → (d_out, d_in); mirrors
    /// `ModelConfig.proj_shapes` in model.py (order matters: it is the
    /// manifest's alphabetical flattening domain).
    pub fn proj_shapes(&self) -> Vec<(String, usize, usize)> {
        let mut v = Vec::new();
        for l in 0..self.n_layers {
            v.push((format!("l{l}.wq"), self.d_model, self.d_model));
            v.push((format!("l{l}.wk"), self.d_model, self.d_model));
            v.push((format!("l{l}.wv"), self.d_model, self.d_model));
            v.push((format!("l{l}.wo"), self.d_model, self.d_model));
            v.push((format!("l{l}.w1"), self.d_ff, self.d_model));
            v.push((format!("l{l}.w2"), self.d_model, self.d_ff));
        }
        v
    }

    pub fn backbone_params(&self) -> u64 {
        let mut n = (self.vocab * self.d_model) as u64;
        n += self
            .proj_shapes()
            .iter()
            .map(|(_, o, i)| (o * i) as u64)
            .sum::<u64>();
        n += ((2 * self.n_layers + 1) * self.d_model) as u64;
        if self.n_classes > 0 {
            n += (self.n_classes * self.d_model) as u64;
        }
        n
    }

    pub fn projections(&self) -> Vec<crate::peft::memory::Projection> {
        self.proj_shapes()
            .iter()
            .map(|&(_, o, i)| crate::peft::memory::Projection { d_out: o as u64, d_in: i as u64 })
            .collect()
    }
}

/// LR schedule shapes from the paper's search spaces (Tables 5–7): linear
/// decay with a warmup ratio.
#[derive(Debug, Clone, PartialEq)]
pub struct TrainCfg {
    pub lr: f64,
    pub warmup_ratio: f64,
    pub steps: usize,
    pub seed: u64,
    pub log_every: usize,
    /// Epochs metadata for paper-parity reporting (steps = epochs × batches).
    pub epochs: usize,
}

impl Default for TrainCfg {
    fn default() -> TrainCfg {
        TrainCfg { lr: 3e-3, warmup_ratio: 0.06, steps: 300, seed: 42, log_every: 25, epochs: 3 }
    }
}

/// PEFT method selection for a run.
#[derive(Debug, Clone, PartialEq)]
pub struct PeftCfg {
    pub method: MethodKind,
    pub strategy: crate::peft::Strategy,
    /// Fraction of neurons allowed to adapt (Figure 6); 1.0 = all.
    pub neuron_fraction: f64,
}

impl Default for PeftCfg {
    fn default() -> PeftCfg {
        PeftCfg {
            method: MethodKind::NeuroAda { k: 1 },
            strategy: crate::peft::Strategy::Magnitude,
            neuron_fraction: 1.0,
        }
    }
}

/// A full experiment config (one fine-tuning run).
#[derive(Debug, Clone)]
pub struct RunCfg {
    pub size: String,
    pub task: String,
    pub train: TrainCfg,
    pub peft: PeftCfg,
    pub artifacts_dir: String,
    pub out_dir: String,
}

impl Default for RunCfg {
    fn default() -> RunCfg {
        RunCfg {
            size: "nano".into(),
            task: "cs-boolq".into(),
            train: TrainCfg::default(),
            peft: PeftCfg::default(),
            artifacts_dir: "artifacts".into(),
            out_dir: "runs".into(),
        }
    }
}

/// Errors from config parsing/validation.
#[derive(Debug)]
pub struct ConfigError(pub String);

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "config error: {}", self.0)
    }
}
impl std::error::Error for ConfigError {}

fn err(msg: impl Into<String>) -> ConfigError {
    ConfigError(msg.into())
}

impl RunCfg {
    /// Build from parsed TOML sections, starting from defaults.
    pub fn from_toml(doc: &BTreeMap<String, BTreeMap<String, TomlValue>>) -> Result<RunCfg, ConfigError> {
        let mut cfg = RunCfg::default();
        for (section, kv) in doc {
            match section.as_str() {
                "run" | "" => {
                    for (k, v) in kv {
                        match k.as_str() {
                            "size" => cfg.size = v.as_str().ok_or_else(|| err("run.size: string"))?.into(),
                            "task" => cfg.task = v.as_str().ok_or_else(|| err("run.task: string"))?.into(),
                            "artifacts_dir" => cfg.artifacts_dir = v.as_str().ok_or_else(|| err("string"))?.into(),
                            "out_dir" => cfg.out_dir = v.as_str().ok_or_else(|| err("string"))?.into(),
                            _ => return Err(err(format!("unknown key run.{k}"))),
                        }
                    }
                }
                "train" => {
                    for (k, v) in kv {
                        match k.as_str() {
                            "lr" => cfg.train.lr = v.as_f64().ok_or_else(|| err("train.lr: number"))?,
                            "warmup_ratio" => cfg.train.warmup_ratio = v.as_f64().ok_or_else(|| err("number"))?,
                            "steps" => cfg.train.steps = v.as_usize().ok_or_else(|| err("int"))?,
                            "seed" => cfg.train.seed = v.as_usize().ok_or_else(|| err("int"))? as u64,
                            "log_every" => cfg.train.log_every = v.as_usize().ok_or_else(|| err("int"))?,
                            "epochs" => cfg.train.epochs = v.as_usize().ok_or_else(|| err("int"))?,
                            _ => return Err(err(format!("unknown key train.{k}"))),
                        }
                    }
                }
                "peft" => {
                    let mut method = "neuroada".to_string();
                    let mut k = 1usize;
                    let mut r = 8usize;
                    for (key, v) in kv {
                        match key.as_str() {
                            "method" => method = v.as_str().ok_or_else(|| err("peft.method: string"))?.into(),
                            "k" => k = v.as_usize().ok_or_else(|| err("int"))?,
                            "rank" => r = v.as_usize().ok_or_else(|| err("int"))?,
                            "strategy" => {
                                cfg.peft.strategy = crate::peft::Strategy::parse(
                                    v.as_str().ok_or_else(|| err("string"))?,
                                )
                                .ok_or_else(|| err("unknown strategy"))?
                            }
                            "neuron_fraction" => {
                                cfg.peft.neuron_fraction =
                                    v.as_f64().ok_or_else(|| err("number"))?
                            }
                            _ => return Err(err(format!("unknown key peft.{key}"))),
                        }
                    }
                    cfg.peft.method = match method.as_str() {
                        "neuroada" => MethodKind::NeuroAda { k },
                        "masked" => MethodKind::Masked { k },
                        "lora" => MethodKind::Lora { r },
                        "bitfit" => MethodKind::BitFit,
                        "full" => MethodKind::Full,
                        other => return Err(err(format!("unknown method {other}"))),
                    };
                }
                other => return Err(err(format!("unknown section [{other}]"))),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn load(path: &str) -> Result<RunCfg, ConfigError> {
        let text = std::fs::read_to_string(path).map_err(|e| err(format!("{path}: {e}")))?;
        let doc = parse_toml(&text).map_err(|e| err(format!("{path}: {e}")))?;
        RunCfg::from_toml(&doc)
    }

    pub fn validate(&self) -> Result<(), ConfigError> {
        if presets::model(&self.size).is_none() {
            return Err(err(format!("unknown model size {:?}", self.size)));
        }
        if !(0.0..=1.0).contains(&self.peft.neuron_fraction) {
            return Err(err("peft.neuron_fraction must be in [0, 1]"));
        }
        if self.train.lr <= 0.0 || self.train.lr > 1.0 {
            return Err(err(format!("train.lr {} out of range", self.train.lr)));
        }
        if self.train.steps == 0 {
            return Err(err("train.steps must be > 0"));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const EXAMPLE: &str = r#"
# fine-tune nano on the boolq-like task
[run]
size = "nano"
task = "cs-boolq"

[train]
lr = 0.003
steps = 120
seed = 7

[peft]
method = "neuroada"
k = 4
strategy = "magnitude"
neuron_fraction = 0.5
"#;

    #[test]
    fn parses_full_config() {
        let doc = parse_toml(EXAMPLE).unwrap();
        let cfg = RunCfg::from_toml(&doc).unwrap();
        assert_eq!(cfg.size, "nano");
        assert_eq!(cfg.train.lr, 0.003);
        assert_eq!(cfg.train.steps, 120);
        assert_eq!(cfg.peft.method, MethodKind::NeuroAda { k: 4 });
        assert_eq!(cfg.peft.neuron_fraction, 0.5);
    }

    #[test]
    fn rejects_unknown_keys() {
        let doc = parse_toml("[train]\nlearning_rate = 0.1\n").unwrap();
        assert!(RunCfg::from_toml(&doc).is_err());
    }

    #[test]
    fn rejects_bad_values() {
        for bad in [
            "[run]\nsize = \"gigantic\"\n",
            "[train]\nlr = -1.0\n",
            "[peft]\nneuron_fraction = 1.5\n",
            "[peft]\nmethod = \"adapters\"\n",
        ] {
            let doc = parse_toml(bad).unwrap();
            assert!(RunCfg::from_toml(&doc).is_err(), "{bad}");
        }
    }

    #[test]
    fn defaults_are_valid() {
        RunCfg::default().validate().unwrap();
    }

    #[test]
    fn proj_shapes_match_python() {
        let m = presets::model("nano").unwrap();
        let shapes = m.proj_shapes();
        assert_eq!(shapes.len(), 12);
        assert_eq!(shapes[0], ("l0.wq".into(), 64, 64));
        assert_eq!(shapes[4], ("l0.w1".into(), 256, 64));
        assert_eq!(m.backbone_params(), 115_008);
    }
}
