//! TOML-subset parser (see module docs in `config`): sections, scalar values,
//! flat arrays, comments. Deliberately strict — anything outside the subset
//! is an error, never a silent misread.

use std::collections::BTreeMap;

/// A parsed TOML value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Some(*i as usize),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Array(a) => Some(a),
            _ => None,
        }
    }
}

/// Parse a document into section → key → value. Keys before any `[section]`
/// land in the "" section.
pub fn parse_toml(
    src: &str,
) -> Result<BTreeMap<String, BTreeMap<String, TomlValue>>, String> {
    let mut doc: BTreeMap<String, BTreeMap<String, TomlValue>> = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in src.lines().enumerate() {
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| format!("line {}: unterminated section", lineno + 1))?
                .trim();
            if name.is_empty() || !name.chars().all(|c| c.is_alphanumeric() || "._-".contains(c)) {
                return Err(format!("line {}: bad section name {name:?}", lineno + 1));
            }
            section = name.to_string();
            doc.entry(section.clone()).or_default();
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
        let key = line[..eq].trim();
        if key.is_empty() || !key.chars().all(|c| c.is_alphanumeric() || "_-".contains(c)) {
            return Err(format!("line {}: bad key {key:?}", lineno + 1));
        }
        let value = parse_value(line[eq + 1..].trim())
            .map_err(|e| format!("line {}: {e}", lineno + 1))?;
        doc.entry(section.clone())
            .or_default()
            .insert(key.to_string(), value);
    }
    Ok(doc)
}

/// Strip a `#` comment, respecting quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest.strip_suffix('"').ok_or("unterminated string")?;
        if inner.contains('"') {
            return Err("embedded quote (escapes unsupported in subset)".into());
        }
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest.strip_suffix(']').ok_or("unterminated array")?.trim();
        if inner.is_empty() {
            return Ok(TomlValue::Array(vec![]));
        }
        let items: Result<Vec<TomlValue>, String> = split_top_level(inner)
            .into_iter()
            .map(|p| parse_value(p.trim()))
            .collect();
        return Ok(TomlValue::Array(items?));
    }
    // numbers: int if no '.', 'e', 'E'
    let is_float = s.contains('.') || s.contains('e') || s.contains('E');
    if is_float {
        s.parse::<f64>()
            .map(TomlValue::Float)
            .map_err(|_| format!("bad float {s:?}"))
    } else {
        s.parse::<i64>()
            .map(TomlValue::Int)
            .map_err(|_| format!("bad value {s:?}"))
    }
}

/// Split an array body on commas (strings may contain commas).
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sections_and_scalars() {
        let doc = parse_toml(
            "top = 1\n[a]\nx = \"hi\" # comment\ny = 2.5\nz = true\n[a.b]\nw = -3\n",
        )
        .unwrap();
        assert_eq!(doc[""]["top"], TomlValue::Int(1));
        assert_eq!(doc["a"]["x"], TomlValue::Str("hi".into()));
        assert_eq!(doc["a"]["y"], TomlValue::Float(2.5));
        assert_eq!(doc["a"]["z"], TomlValue::Bool(true));
        assert_eq!(doc["a.b"]["w"], TomlValue::Int(-3));
    }

    #[test]
    fn arrays() {
        let doc = parse_toml("[s]\nlrs = [0.001, 0.003, 0.01]\nnames = [\"a\", \"b,c\"]\n").unwrap();
        let lrs = doc["s"]["lrs"].as_array().unwrap();
        assert_eq!(lrs.len(), 3);
        assert_eq!(lrs[1].as_f64(), Some(0.003));
        let names = doc["s"]["names"].as_array().unwrap();
        assert_eq!(names[1].as_str(), Some("b,c"));
    }

    #[test]
    fn comments_in_strings_kept() {
        let doc = parse_toml("[s]\nx = \"a#b\"\n").unwrap();
        assert_eq!(doc["s"]["x"].as_str(), Some("a#b"));
    }

    #[test]
    fn errors_are_loud() {
        assert!(parse_toml("[unclosed\n").is_err());
        assert!(parse_toml("[s]\nnovalue\n").is_err());
        assert!(parse_toml("[s]\nx = \n").is_err());
        assert!(parse_toml("[s]\nx = 1.2.3\n").is_err());
        assert!(parse_toml("[s]\nbad key = 1\n").is_err());
    }

    #[test]
    fn scientific_notation() {
        let doc = parse_toml("[t]\nlr = 3e-3\n").unwrap();
        assert_eq!(doc["t"]["lr"].as_f64(), Some(3e-3));
    }
}
