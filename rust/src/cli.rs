//! Command-line interface (hand-rolled: clap is unavailable offline).
//!
//! Subcommands:
//!   repro <id>     regenerate a paper table/figure (table1, fig4..fig7,
//!                  table2, table3, table4, sweeps, all)
//!   pretrain       build + cache a backbone checkpoint
//!   train          one fine-tuning run (method × task), merge + eval
//!   eval           zero-shot eval of a cached backbone on a task
//!   serve          multi-adapter serving engine (registry + micro-batching
//!                  + streaming greedy decode via --generate; encoder sizes
//!                  serve GLUE classification with exact eval parity;
//!                  requests may name weighted adapter mixtures "a:0.7+b:0.3")
//!   compose        average a weighted adapter mixture into one checkpointed
//!                  adapter (AdaMix-style; bitwise-equal to online mixture)
//!   audit          memory audit: analytic (Eq. 5/6) vs measured bytes
//!   tasks          list the 23 synthetic tasks
//!
//! Flags use `--key value` (or `--flag` for booleans).

use std::collections::BTreeMap;

/// Parsed argv: subcommand, positional args, `--key value` options.
#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: String,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
}

/// Parse argv (excluding argv[0]).
pub fn parse_args(argv: &[String]) -> Result<Args, String> {
    let mut a = Args::default();
    let mut it = argv.iter().peekable();
    if let Some(first) = it.peek() {
        if !first.starts_with("--") {
            a.subcommand = it.next().unwrap().clone();
        }
    }
    while let Some(arg) = it.next() {
        if let Some(key) = arg.strip_prefix("--") {
            if key.is_empty() {
                return Err("bad flag '--'".into());
            }
            // boolean flag if next token is absent or another flag
            match it.peek() {
                Some(v) if !v.starts_with("--") => {
                    a.options.insert(key.to_string(), it.next().unwrap().clone());
                }
                _ => {
                    a.options.insert(key.to_string(), "true".to_string());
                }
            }
        } else {
            a.positional.push(arg.clone());
        }
    }
    Ok(a)
}

impl Args {
    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    pub fn opt_or(&self, key: &str, default: &str) -> String {
        self.opt(key).unwrap_or(default).to_string()
    }

    pub fn opt_usize(&self, key: &str) -> Result<Option<usize>, String> {
        match self.opt(key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| format!("--{key}: expected integer, got {v:?}")),
        }
    }

    /// Like [`Args::opt_usize`] but rejects 0 with a typed error — for
    /// flags where zero can never mean anything (`--slots 0` used to be
    /// silently clamped to 1) as opposed to a "disabled/unbounded"
    /// sentinel like `--quota 0` or `--kv-pages 0`.
    pub fn opt_nonzero_usize(&self, key: &str) -> Result<Option<usize>, String> {
        match self.opt_usize(key)? {
            Some(0) => Err(format!("--{key}: must be >= 1 (got 0)")),
            v => Ok(v),
        }
    }

    pub fn opt_f64(&self, key: &str) -> Result<Option<f64>, String> {
        match self.opt(key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| format!("--{key}: expected number, got {v:?}")),
        }
    }

    pub fn flag(&self, key: &str) -> bool {
        matches!(self.opt(key), Some("true") | Some("1") | Some("yes"))
    }
}

pub const USAGE: &str = "\
neuroada — NeuroAda reproduction (rust coordinator over AOT JAX/Pallas artifacts)

USAGE: neuroada <subcommand> [--flags]

SUBCOMMANDS
  repro <id>        regenerate paper results: table1 | fig4 | fig5 | fig6 |
                    fig7 | table2 | table3 | table4 | sweeps | all
  pretrain          build + cache a backbone (--size nano --steps 16000)
  train             one run: --size nano --task cs-boolq --method neuroada
                    [--k 1] [--rank 8] [--strategy magnitude] [--fraction 1.0]
                    [--steps 1500] [--lr 8e-3] [--config cfg.toml]
  eval              zero-shot eval: --size nano --task cs-boolq [--n 200]
  serve             multi-adapter serving: --size nano [--adapters 4]
                    [--ckpt-dir DIR] [--requests 256] [--clients 4]
                    [--workers N] [--queue 256] [--max-batch B]
                    [--wait-ms 10] [--capacity 2] [--promote 3] [--host]
                    [--threads N] [--generate] [--max-new 16] [--slots 8]
                    [--kv-pages N] [--quota N] [--temp T] [--top-k K]
                    [--backbone-dtype f32|bf16|int8]
                    [--cls] [--task glue-sst2]
                    [--metrics-addr HOST:PORT] [--metrics-out FILE]
                    [--trace-out FILE]
                    (observability: --metrics-addr serves GET /metrics
                    (Prometheus text) and /metrics.json (JSON snapshot)
                    for the run's duration; --metrics-out writes the final
                    snapshot JSON; --trace-out enables request tracing and
                    writes a Chrome trace-event JSON loadable in Perfetto,
                    asserting stage spans cover >=95% of each request's
                    end-to-end latency. NEUROADA_LOG=error|warn|info|debug
                    filters the serve log lines. See docs/observability.md.
                    --generate streams decode tokens through the KV-cached
                    slot scheduler instead of scoring options; --temp/--top-k
                    switch greedy to seeded sampling; --threads N sizes the
                    server's ONE persistent kernel pool — batched matmuls,
                    attention, and the per-token decode step all partition
                    across it, bit-identical to serial — default
                    NEUROADA_THREADS or serial; --backbone-dtype bf16|int8
                    holds the frozen backbone (and every merged copy)
                    quantized, dequantizing in-register on the host path —
                    adapters stay f32, resident bytes drop ~2x/4x;
                    --kv-pages N caps the block-paged KV pool at N pages
                    (0 = unbounded) — under a finite budget the scheduler
                    shares prompt-prefix pages copy-on-write across slots
                    and spills/restores the newest stream instead of
                    rejecting; --slots must be >= 1.
                    Encoder sizes, e.g.
                    --size enc-micro [--cls], serve a GLUE task's dev set
                    as classification requests on both weight views and
                    assert the served metric reproduces the offline
                    encoder eval exactly.
                    Requests may address a weighted adapter mixture with a
                    composite spec -- \"a+b\" (uniform) or \"a:0.7+b:0.3\" --
                    composed on resolve as one sparse k-way union and cached
                    (LRU); the admission quota charges every component part.
                    See docs/serving.md \"Adapter composition\")
  compose           average a mixture into ONE checkpointed adapter
                    (the AdaMix inference trick): --size nano
                    --spec \"a:0.7+b:0.3\" --out-name blend
                    [--ckpt-dir DIR] [--synth-missing] [--out DIR]
                    (parts load from <ckpt-dir>/<name>/deltas;
                    --synth-missing synthesizes absent parts, seeded --
                    the no-training smoke path; output lands under
                    <ckpt-dir>/<out-name>/deltas, or <out>/composed/...
                    without --ckpt-dir. Serving the composed adapter is
                    bitwise-equal to serving the spec online: both paths
                    compose in canonical spec order and BF16-round once)
  lifecycle         fine-tune-as-a-service against a live server:
                    --size nano [--task cs-boolq] [--adapter-name svc]
                    [--jobs 2] [--steps 12] [--k 1] [--budget 0]
                    [--eval-n 32] [--sigma 0.05] [--slice 16]
                    [--corrupt-last] [--pjrt] [--requests 64] [--clients 2]
                    [--capacity 2] [--half-life 30] [--rate-promote 3]
                    [--rate-demote 0.25] [--count-policy] [--threads N]
                    [--metrics-addr HOST:PORT] [--metrics-out FILE]
                    [--trace-out FILE]
                    (each job trains a NeuroAda candidate — artifact-free
                    host hill-climb by default, --pjrt for the AOT train
                    artifact — checkpoints it under --out, A/Bs it against
                    the incumbent on a held-out slice, and promotes with a
                    versioned atomic cutover (name@vN) or rolls back; the
                    registry runs the decayed-rate promotion policy unless
                    --count-policy; --budget N apportions N trainable
                    params across projections by weight mass;
                    --corrupt-last injects a losing candidate into the
                    final job to demonstrate rollback. Lifecycle events
                    surface in the metrics table/Prometheus/JSON and the
                    trace. See docs/lifecycle.md)
  audit             memory audit table: [--size nano] [--k 1]
  tasks             list the 23 synthetic tasks

COMMON FLAGS
  --artifacts DIR   artifact directory (default: artifacts)
  --out DIR         run output directory (default: runs)
  --smoke           tiny budgets (CI smoke test)
  --pretrain-steps N --steps N --eval-n N --seed N --lr X
";

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &[&str]) -> Args {
        parse_args(&s.iter().map(|x| x.to_string()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let a = args(&["repro", "fig4", "--size", "nano", "--smoke", "--steps", "50"]);
        assert_eq!(a.subcommand, "repro");
        assert_eq!(a.positional, vec!["fig4"]);
        assert_eq!(a.opt("size"), Some("nano"));
        assert!(a.flag("smoke"));
        assert_eq!(a.opt_usize("steps").unwrap(), Some(50));
    }

    #[test]
    fn boolean_flag_at_end() {
        let a = args(&["train", "--smoke"]);
        assert!(a.flag("smoke"));
    }

    #[test]
    fn bad_numbers_error() {
        let a = args(&["train", "--steps", "abc"]);
        assert!(a.opt_usize("steps").is_err());
    }

    #[test]
    fn zero_rejected_where_nonzero_required() {
        // `serve --slots 0` must be a typed CLI error, not a silent clamp
        let a = args(&["serve", "--slots", "0"]);
        let err = a.opt_nonzero_usize("slots").unwrap_err();
        assert!(err.contains("--slots"), "error names the flag: {err}");
        assert!(err.contains(">= 1"), "error states the bound: {err}");
        // valid and absent values pass through unchanged
        let a = args(&["serve", "--slots", "8"]);
        assert_eq!(a.opt_nonzero_usize("slots").unwrap(), Some(8));
        let a = args(&["serve"]);
        assert_eq!(a.opt_nonzero_usize("slots").unwrap(), None);
    }
}
