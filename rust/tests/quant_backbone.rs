//! ISSUE-7 acceptance tests: quantized frozen backbone (bf16/int8) end to
//! end through the `MatRef` weight view.
//!
//! 1. **Logit bound**: the planned batch forward over a quantized nano
//!    backbone stays within the documented logit-deviation bound
//!    (`BackboneDtype::logit_tol`) of the f32 forward, and the pooled
//!    quantized forward is bitwise identical to the serial one (the
//!    partition invariant is dtype-independent).
//! 2. **Cls stability**: on a GLUE dev slice (enc-micro), quantized
//!    `cls_predict` reproduces every f32 argmax whose winning margin
//!    exceeds twice the documented bound — within the bound a flip is
//!    arithmetically impossible, so any such flip means the kernels broke.
//! 3. **Registry**: a registry built `with_dtype(int8)` holds ≤ 0.5× the
//!    f32 resident bytes, and merging an adapter re-quantizes the merged
//!    copy at the same dtype (no f32 copies accumulate at steady state).
//! 4. **Decode**: the KV-cached step over a quantized backbone is bitwise
//!    identical to a from-scratch replay at every position — the
//!    dequantize-in-register row kernels must not perturb cache contents.

use neuroada::bench::serve_bench::{randomize_zero_head, synth_adapter};
use neuroada::config::presets;
use neuroada::data::{cls_batch, example_stream, tasks, Split};
use neuroada::model::init::init_params;
use neuroada::model::{DecodeState, PlannedModel};
use neuroada::serve::{AdapterRegistry, RegistryCfg};
use neuroada::tensor::pool::KernelPool;
use neuroada::tensor::quant::{BackboneDtype, QuantStore};
use neuroada::util::nan_safe_argmax;
use neuroada::util::rng::Rng;

fn batch_inputs(cfg: &neuroada::config::ModelCfg, b: usize) -> (Vec<i32>, Vec<f32>, Vec<i32>) {
    let tokens: Vec<i32> =
        (0..b * cfg.seq).map(|i| 4 + ((i * 11) % (cfg.vocab - 4)) as i32).collect();
    let pad = vec![1.0f32; b * cfg.seq];
    let last: Vec<i32> = (0..b).map(|i| (cfg.seq - 1 - i % 3) as i32).collect();
    (tokens, pad, last)
}

/// Acceptance: quantized-backbone logits within the documented bound of
/// f32 on nano, serial ≡ pooled bitwise per dtype.
#[test]
fn quant_logits_within_documented_bound_on_nano() {
    let cfg = presets::model("nano").unwrap();
    let backbone = init_params(&cfg, &mut Rng::new(21));
    let (tokens, pad, last) = batch_inputs(&cfg, 4);
    let serial = KernelPool::serial();
    let pool3 = KernelPool::new(3);
    let want = PlannedModel::resolve(&cfg, &backbone, None, &serial)
        .unwrap()
        .lm_logits_at(&tokens, &pad, &last, 4)
        .unwrap();
    let scale = want.data.iter().fold(1.0f32, |m, v| m.max(v.abs()));
    for dtype in [BackboneDtype::Bf16, BackboneDtype::I8] {
        let q = QuantStore::from_store(&backbone, dtype).unwrap();
        let got = PlannedModel::resolve_from(&cfg, &q, None, &serial)
            .unwrap()
            .lm_logits_at(&tokens, &pad, &last, 4)
            .unwrap();
        let tol = dtype.logit_tol() * scale;
        let diff = want.max_abs_diff(&got);
        assert!(
            diff <= tol,
            "{}: logit deviation {diff} exceeds the documented bound {tol}",
            dtype.name()
        );
        assert!(diff > 0.0, "{}: quantization must actually change something", dtype.name());
        let pooled = PlannedModel::resolve_from(&cfg, &q, None, &pool3)
            .unwrap()
            .lm_logits_at(&tokens, &pad, &last, 4)
            .unwrap();
        assert_eq!(got.data, pooled.data, "{}: pooled must equal serial bitwise", dtype.name());
    }
}

/// Acceptance: on a GLUE dev slice, every f32 prediction whose winning
/// margin clears 2× the documented logit bound survives quantization
/// (within the bound, per-class deviation ≤ tol each way cannot flip a
/// margin > 2·tol). The slice must contain such examples — an all-tight
/// slice would make the test vacuous.
#[test]
fn quant_cls_argmax_stable_on_glue_dev_slice() {
    let cfg = presets::model("enc-micro").unwrap();
    let mut backbone = init_params(&cfg, &mut Rng::new(5));
    assert!(randomize_zero_head(&cfg, &mut backbone, 0xEAD).unwrap());
    let task = tasks::by_name("glue-sst2").unwrap();
    let n = 16;
    let examples = example_stream(&task, Split::Val, 3, cfg.vocab, cfg.seq, n);
    let cb = cls_batch(&examples, cfg.seq);
    let serial = KernelPool::serial();
    let plan = PlannedModel::resolve(&cfg, &backbone, None, &serial).unwrap();
    let (logits, want) = plan.cls_predict(&cb.tokens, &cb.pad_mask, cb.b).unwrap();
    let nc = cfg.n_classes;
    let scale = logits.data.iter().fold(1.0f32, |m, v| m.max(v.abs()));
    for dtype in [BackboneDtype::Bf16, BackboneDtype::I8] {
        let q = QuantStore::from_store(&backbone, dtype).unwrap();
        let qplan = PlannedModel::resolve_from(&cfg, &q, None, &serial).unwrap();
        let (qlogits, got) = qplan.cls_predict(&cb.tokens, &cb.pad_mask, cb.b).unwrap();
        let tol = dtype.logit_tol() * scale;
        let diff = logits.max_abs_diff(&qlogits);
        assert!(diff <= tol, "{}: cls logit deviation {diff} > bound {tol}", dtype.name());
        let mut checked = 0;
        for bi in 0..cb.b {
            let row = &logits.data[bi * nc..(bi + 1) * nc];
            let top = row[want[bi]];
            let margin = row
                .iter()
                .enumerate()
                .filter(|(c, _)| *c != want[bi])
                .map(|(_, &v)| top - v)
                .fold(f32::INFINITY, f32::min);
            if margin > 2.0 * tol {
                assert_eq!(
                    got[bi],
                    want[bi],
                    "{}: example {bi} flipped despite margin {margin} > 2·tol {tol}",
                    dtype.name()
                );
                checked += 1;
            }
        }
        assert!(checked > 0, "{}: no example cleared the margin — vacuous slice", dtype.name());
    }
}

/// Acceptance: int8 registry residency ≤ 0.5× f32, and merges re-quantize.
#[test]
fn int8_registry_halves_bytes_and_requantizes_merges() {
    let cfg = presets::model("nano").unwrap();
    let backbone = init_params(&cfg, &mut Rng::new(9));
    let f32_bytes = backbone.total_bytes();
    let reg = AdapterRegistry::with_dtype(
        cfg.clone(),
        backbone.clone(),
        RegistryCfg { merged_capacity: 2, promote_after: 1, ..RegistryCfg::default() },
        BackboneDtype::I8,
    )
    .unwrap();
    assert_eq!(reg.backbone_dtype(), BackboneDtype::I8);
    assert!(
        reg.backbone_bytes() * 2 <= f32_bytes,
        "int8 backbone {} B must be <= 0.5x f32 {} B",
        reg.backbone_bytes(),
        f32_bytes
    );
    let deltas = synth_adapter(&cfg, &backbone, 1, 42).unwrap();
    reg.register("a", deltas).unwrap();
    let merged = reg.merge_now("a").unwrap();
    assert_eq!(merged.dtype(), BackboneDtype::I8, "merged copies re-quantize at merge time");
    // the merged quantized model actually serves
    let serial = KernelPool::serial();
    let (tokens, pad, last) = batch_inputs(&cfg, 2);
    let logits = merged
        .planned(&cfg, &serial)
        .unwrap()
        .lm_logits_at(&tokens, &pad, &last, 2)
        .unwrap();
    assert!(logits.data.iter().all(|v| v.is_finite()));
    // ... and so does the bypass view over the quantized backbone
    let bypass = reg.bypass("a").unwrap();
    let blogits = bypass
        .planned(&cfg, &serial)
        .unwrap()
        .lm_logits_at(&tokens, &pad, &last, 2)
        .unwrap();
    assert!(blogits.data.iter().all(|v| v.is_finite()));
}

/// Acceptance: the quantized KV-cached step is bitwise identical to a
/// from-scratch replay at every position (same dots in the same order —
/// a cache bug in the dequantizing row kernels would surface here).
#[test]
fn quant_decode_step_cached_matches_replay_bitwise() {
    let cfg = presets::model("nano").unwrap();
    let backbone = init_params(&cfg, &mut Rng::new(31));
    let serial = KernelPool::serial();
    let prompt: Vec<i32> = (0..12).map(|i| 4 + (i * 7) % 40).collect();
    let gen = 4;
    for dtype in [BackboneDtype::Bf16, BackboneDtype::I8] {
        let q = QuantStore::from_store(&backbone, dtype).unwrap();
        let plan = PlannedModel::resolve_from(&cfg, &q, None, &serial).unwrap();
        // cached continuation
        let mut st = DecodeState::new(&cfg);
        let mut lg = Vec::new();
        for &t in &prompt {
            lg = plan.forward_step(t, &mut st).unwrap();
        }
        let mut toks = Vec::new();
        let mut cached_logits = Vec::new();
        for _ in 0..gen {
            let next = nan_safe_argmax(lg.iter().copied()).unwrap() as i32;
            toks.push(next);
            lg = plan.forward_step(next, &mut st).unwrap();
            cached_logits.push(lg.clone());
        }
        // from-scratch replay of the same token sequence
        for g in 0..gen {
            let mut rst = DecodeState::new(&cfg);
            let mut rlg = Vec::new();
            for &t in prompt.iter().chain(&toks[..=g]) {
                rlg = plan.forward_step(t, &mut rst).unwrap();
            }
            assert_eq!(
                rlg,
                cached_logits[g],
                "{}: replay logits diverge from cached at generated position {g}",
                dtype.name()
            );
        }
    }
}
