//! ISSUE-3/ISSUE-5 acceptance tests for the planned forward + the
//! persistent kernel pool.
//!
//! 1. **Parity**: the zero-copy [`PlannedModel`] reproduces the
//!    pre-refactor forward's logits to ≤ 1e-6 on nano for all four of
//!    {merged, bypass} × {batch `lm_logits_at`, KV-cached `forward_step`}.
//!    The pre-refactor path is kept verbatim as
//!    `bench::forward_bench::legacy::LegacyModel`; in practice the batch
//!    kernels are bit-identical, so the observed diff is 0.0.
//! 2. **Pooled kernels are bitwise serial** (ISSUE 5/7): `gemm_nt` through
//!    the unified `Kernel` dispatch — Scalar and Blocked, serial and
//!    pooled — the `d_out`-partitioned decode step, and the pooled
//!    attention (batched across rows, step across heads) equal the serial
//!    scalar oracle BITWISE on randomized odd shapes and thread counts,
//!    via the in-repo property framework.
//! 3. **Pool reuse**: one pool serves many forwards without spawning
//!    anything new — asserted via pool-internal counters, not timing.

use neuroada::bench::forward_bench::legacy::LegacyModel;
use neuroada::bench::serve_bench::synth_adapter;
use neuroada::config::presets;
use neuroada::model::init::init_params;
use neuroada::model::{DecodeState, DeltaOverlay, PlannedModel};
use neuroada::tensor::ops::Kernel;
use neuroada::tensor::pool::KernelPool;
use neuroada::tensor::quant::MatRef;
use neuroada::tensor::Tensor;
use neuroada::testing::{prop_check, PropConfig};
use neuroada::util::rng::Rng;

fn nano() -> (neuroada::config::ModelCfg, neuroada::runtime::ValueStore) {
    let cfg = presets::model("nano").unwrap();
    let backbone = init_params(&cfg, &mut Rng::new(77));
    (cfg, backbone)
}

fn batch_inputs(cfg: &neuroada::config::ModelCfg, b: usize) -> (Vec<i32>, Vec<f32>, Vec<i32>) {
    let tokens: Vec<i32> = (0..b * cfg.seq).map(|i| 4 + ((i * 11) % (cfg.vocab - 4)) as i32).collect();
    let pad = vec![1.0f32; b * cfg.seq];
    let last: Vec<i32> = (0..b).map(|i| (cfg.seq - 1 - i % 3) as i32).collect();
    (tokens, pad, last)
}

/// Acceptance: planned batch forward == pre-refactor batch forward to
/// ≤ 1e-6, merged AND bypass, serial AND pooled.
#[test]
fn planned_batch_matches_legacy_merged_and_bypass() {
    let (cfg, backbone) = nano();
    let deltas = synth_adapter(&cfg, &backbone, 2, 42).unwrap();
    let overlay = DeltaOverlay::new(&deltas);
    let (tokens, pad, last) = batch_inputs(&cfg, 4);
    let serial = KernelPool::serial();
    let pool4 = KernelPool::new(4);
    for (label, ov) in [("merged", None), ("bypass", Some(&overlay))] {
        let legacy = LegacyModel { cfg: &cfg, params: &backbone, overlay: ov };
        let want = legacy.lm_logits_at(&tokens, &pad, &last, 4).unwrap();
        for pool in [&serial, &pool4] {
            let plan = PlannedModel::resolve(&cfg, &backbone, ov, pool).unwrap();
            let got = plan.lm_logits_at(&tokens, &pad, &last, 4).unwrap();
            let diff = want.max_abs_diff(&got);
            assert!(
                diff <= 1e-6,
                "{label} threads={}: plan vs legacy diff {diff}",
                pool.threads()
            );
        }
    }
    // the bypass genuinely differs from the raw backbone (the overlay bound)
    let raw = PlannedModel::new(&cfg, &backbone).unwrap().lm_logits_at(&tokens, &pad, &last, 4).unwrap();
    let by = PlannedModel::resolve(&cfg, &backbone, Some(&overlay), &KernelPool::serial())
        .unwrap()
        .lm_logits_at(&tokens, &pad, &last, 4)
        .unwrap();
    assert!(raw.max_abs_diff(&by) > 1e-5, "overlay must change logits");
}

/// Acceptance: planned KV-cached step == pre-refactor step to ≤ 1e-6 at
/// every position, merged AND bypass.
#[test]
fn planned_step_matches_legacy_merged_and_bypass() {
    let (cfg, backbone) = nano();
    let deltas = synth_adapter(&cfg, &backbone, 1, 43).unwrap();
    let overlay = DeltaOverlay::new(&deltas);
    let toks: Vec<i32> = (0..16).map(|i| 4 + (i * 7) % 40).collect();
    for (label, ov) in [("merged", None), ("bypass", Some(&overlay))] {
        let legacy = LegacyModel { cfg: &cfg, params: &backbone, overlay: ov };
        let plan = PlannedModel::resolve(&cfg, &backbone, ov, &KernelPool::serial()).unwrap();
        let mut sl = DecodeState::new(&cfg);
        let mut sp = DecodeState::new(&cfg);
        for (pos, &t) in toks.iter().enumerate() {
            let want = legacy.forward_step(t, &mut sl).unwrap();
            let got = plan.forward_step(t, &mut sp).unwrap();
            let diff = want
                .iter()
                .zip(&got)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(diff <= 1e-6, "{label} position {pos}: step diff {diff}");
        }
        assert_eq!(sl.len(), sp.len());
    }
}

/// ISSUE-5/7 property: `gemm_nt` through every `Kernel` × pool width
/// equals the serial Scalar oracle bitwise on odd shapes — m, n, k drawn
/// so they are NOT multiples of the partition or the blocked panel.
#[test]
fn prop_pooled_matmul_bitwise_on_odd_shapes() {
    let serial = KernelPool::serial();
    let pools: Vec<KernelPool> =
        [2usize, 3, 5, 7, 33].iter().map(|&t| KernelPool::new(t)).collect();
    prop_check(PropConfig { cases: 48, max_size: 23, base_seed: 0xF00D }, |rng, size| {
        let m = 1 + rng.below(size.max(1) * 2);
        let n = 1 + rng.below(size.max(1) * 2);
        let k = 1 + rng.below(size.max(1) * 2);
        let a = Tensor::randn(&[m, k], 1.0, rng);
        let b = Tensor::randn(&[n, k], 1.0, rng);
        let mut want = vec![0.0f32; m * n];
        Kernel::Scalar.gemm_nt(&a.data, m, k, MatRef::F32(&b.data), n, &mut want, &serial);
        let mut got = vec![0.0f32; m * n];
        for pool in std::iter::once(&serial).chain(&pools) {
            for kern in [Kernel::Scalar, Kernel::Blocked] {
                got.fill(0.0);
                kern.gemm_nt(&a.data, m, k, MatRef::F32(&b.data), n, &mut got, pool);
                if want != got {
                    return Err(format!(
                        "m={m} n={n} k={k} threads={} {kern:?}: not bitwise equal to \
                         the serial scalar oracle",
                        pool.threads()
                    ));
                }
            }
        }
        Ok(())
    })
    .unwrap();
}

/// ISSUE-5 property: the pooled decode step (the `d_out` partition per
/// projection, pooled attention across heads, pooled LM head over the
/// vocab) is bitwise identical to the serial step at every position,
/// merged AND bypass, across odd pool widths. micro with a lengthened
/// context so the step's attention clears its pooling work floor.
#[test]
fn prop_pooled_step_bitwise_merged_and_bypass() {
    let mut cfg = presets::model("micro").unwrap();
    cfg.seq = 64; // nh·ctx·hd = 4·p·32 crosses the attention pool floor
    let backbone = init_params(&cfg, &mut Rng::new(99));
    let deltas = synth_adapter(&cfg, &backbone, 1, 44).unwrap();
    let overlay = DeltaOverlay::new(&deltas);
    let toks: Vec<i32> = (0..cfg.seq).map(|i| 4 + ((i * 13) % (cfg.vocab - 4)) as i32).collect();
    for threads in [2usize, 3, 5] {
        let pool = KernelPool::new(threads);
        for (label, ov) in [("merged", None), ("bypass", Some(&overlay))] {
            let serial = PlannedModel::resolve(&cfg, &backbone, ov, &KernelPool::serial()).unwrap();
            let pooled = PlannedModel::resolve(&cfg, &backbone, ov, &pool).unwrap();
            let mut ss = DecodeState::new(&cfg);
            let mut sp = DecodeState::new(&cfg);
            for (pos, &t) in toks.iter().enumerate() {
                let want = serial.forward_step(t, &mut ss).unwrap();
                let got = pooled.forward_step(t, &mut sp).unwrap();
                assert_eq!(want, got, "{label} threads={threads} position {pos}");
            }
            // the KV caches themselves are bitwise identical too
            assert_eq!(ss.kv_bytes(), sp.kv_bytes());
        }
    }
}

/// ISSUE-5: pooled batched attention (partitioned across batch rows) is
/// bitwise identical to serial — `hidden` exercises attention directly,
/// and a batch > 1 with per-row pad masks makes the partition non-trivial.
#[test]
fn pooled_batched_attention_bitwise_matches_serial() {
    let (cfg, backbone) = nano();
    let b = 5; // odd batch: partitions unevenly at every pool width
    let (tokens, mut pad, _) = batch_inputs(&cfg, b);
    // ragged pad masks so every batch row attends differently
    for bi in 0..b {
        for t in (cfg.seq - bi)..cfg.seq {
            pad[bi * cfg.seq + t] = 0.0;
        }
    }
    let serial = PlannedModel::new(&cfg, &backbone).unwrap();
    let want = serial.hidden(&tokens, &pad, b).unwrap();
    for threads in [2usize, 3, 8] {
        let pool = KernelPool::new(threads);
        let got = PlannedModel::resolve(&cfg, &backbone, None, &pool)
            .unwrap()
            .hidden(&tokens, &pad, b)
            .unwrap();
        assert_eq!(want.data, got.data, "threads={threads}");
    }
}

/// ISSUE-5: one pool serves many forwards — jobs flow through it, and
/// nothing new is ever spawned (pool-internal counters, not timing).
#[test]
fn pool_reuse_two_forwards_no_worker_leak() {
    let (cfg, backbone) = nano();
    let (tokens, pad, last) = batch_inputs(&cfg, 4);
    let pool = KernelPool::new(3);
    let workers = pool.workers();
    assert!(workers <= 2, "a width-3 pool spawns at most 2 workers");
    let plan = PlannedModel::resolve(&cfg, &backbone, None, &pool).unwrap();
    let first = plan.lm_logits_at(&tokens, &pad, &last, 4).unwrap();
    let jobs_after_first = pool.jobs();
    assert!(jobs_after_first > 0, "the forward must route its kernels through the pool");
    let second = plan.lm_logits_at(&tokens, &pad, &last, 4).unwrap();
    assert_eq!(first.data, second.data, "same plan, same pool, same bits");
    assert!(pool.jobs() > jobs_after_first, "the second forward reuses the same pool");
    assert_eq!(pool.workers(), workers, "reuse spawns no new workers");
    assert!(pool.dispatched() <= pool.jobs());
    // a decode step over the same pool also reuses it
    let mut state = DecodeState::new(&cfg);
    let jobs_before_step = pool.jobs();
    plan.forward_step(4, &mut state).unwrap();
    assert!(pool.jobs() > jobs_before_step, "the step routes through the pool too");
    assert_eq!(pool.workers(), workers);
}

/// Steady-state contract: a resolved plan keeps serving after the overlay
/// handle is gone (views are pre-bound), and re-pooling does not change
/// results.
#[test]
fn plan_survives_overlay_drop_and_repooling() {
    let (cfg, backbone) = nano();
    let deltas = synth_adapter(&cfg, &backbone, 1, 44).unwrap();
    let (tokens, pad, last) = batch_inputs(&cfg, 2);
    let plan = {
        let overlay = DeltaOverlay::new(&deltas);
        PlannedModel::resolve(&cfg, &backbone, Some(&overlay), &KernelPool::serial()).unwrap()
        // overlay dropped here; the plan's scatter views borrow `deltas`
    };
    assert_eq!(plan.bound_deltas(), deltas.len());
    let a = plan.lm_logits_at(&tokens, &pad, &last, 2).unwrap();
    let b = plan.with_pool(&KernelPool::new(3)).lm_logits_at(&tokens, &pad, &last, 2).unwrap();
    assert_eq!(a.data, b.data);
}
