//! ISSUE-3 acceptance tests for the planned forward refactor.
//!
//! 1. **Parity**: the zero-copy [`PlannedModel`] reproduces the
//!    pre-refactor forward's logits to ≤ 1e-6 on nano for all four of
//!    {merged, bypass} × {batch `lm_logits_at`, KV-cached `forward_step`}.
//!    The pre-refactor path is kept verbatim as
//!    `bench::forward_bench::legacy::LegacyModel`; in practice the batch
//!    kernels are bit-identical, so the observed diff is 0.0.
//! 2. **Threading**: the row-partitioned `matmul_nt` equals serial
//!    BITWISE on randomized odd shapes (m, n, k not multiples of the
//!    partition), via the in-repo property framework.

use neuroada::bench::forward_bench::legacy::LegacyModel;
use neuroada::bench::serve_bench::synth_adapter;
use neuroada::config::presets;
use neuroada::model::init::init_params;
use neuroada::model::{DecodeState, DeltaOverlay, PlannedModel};
use neuroada::tensor::ops::{matmul_nt, matmul_nt_threaded};
use neuroada::tensor::Tensor;
use neuroada::testing::{prop_check, PropConfig};
use neuroada::util::rng::Rng;

fn nano() -> (neuroada::config::ModelCfg, neuroada::runtime::ValueStore) {
    let cfg = presets::model("nano").unwrap();
    let backbone = init_params(&cfg, &mut Rng::new(77));
    (cfg, backbone)
}

fn batch_inputs(cfg: &neuroada::config::ModelCfg, b: usize) -> (Vec<i32>, Vec<f32>, Vec<i32>) {
    let tokens: Vec<i32> = (0..b * cfg.seq).map(|i| 4 + ((i * 11) % (cfg.vocab - 4)) as i32).collect();
    let pad = vec![1.0f32; b * cfg.seq];
    let last: Vec<i32> = (0..b).map(|i| (cfg.seq - 1 - i % 3) as i32).collect();
    (tokens, pad, last)
}

/// Acceptance: planned batch forward == pre-refactor batch forward to
/// ≤ 1e-6, merged AND bypass, serial AND threaded.
#[test]
fn planned_batch_matches_legacy_merged_and_bypass() {
    let (cfg, backbone) = nano();
    let deltas = synth_adapter(&cfg, &backbone, 2, 42).unwrap();
    let overlay = DeltaOverlay::new(&deltas);
    let (tokens, pad, last) = batch_inputs(&cfg, 4);
    for (label, ov) in [("merged", None), ("bypass", Some(&overlay))] {
        let legacy = LegacyModel { cfg: &cfg, params: &backbone, overlay: ov };
        let want = legacy.lm_logits_at(&tokens, &pad, &last, 4).unwrap();
        for threads in [1usize, 4] {
            let plan = PlannedModel::resolve(&cfg, &backbone, ov, threads).unwrap();
            let got = plan.lm_logits_at(&tokens, &pad, &last, 4).unwrap();
            let diff = want.max_abs_diff(&got);
            assert!(diff <= 1e-6, "{label} threads={threads}: plan vs legacy diff {diff}");
        }
    }
    // the bypass genuinely differs from the raw backbone (the overlay bound)
    let raw = PlannedModel::new(&cfg, &backbone).unwrap().lm_logits_at(&tokens, &pad, &last, 4).unwrap();
    let by = PlannedModel::resolve(&cfg, &backbone, Some(&overlay), 1)
        .unwrap()
        .lm_logits_at(&tokens, &pad, &last, 4)
        .unwrap();
    assert!(raw.max_abs_diff(&by) > 1e-5, "overlay must change logits");
}

/// Acceptance: planned KV-cached step == pre-refactor step to ≤ 1e-6 at
/// every position, merged AND bypass.
#[test]
fn planned_step_matches_legacy_merged_and_bypass() {
    let (cfg, backbone) = nano();
    let deltas = synth_adapter(&cfg, &backbone, 1, 43).unwrap();
    let overlay = DeltaOverlay::new(&deltas);
    let toks: Vec<i32> = (0..16).map(|i| 4 + (i * 7) % 40).collect();
    for (label, ov) in [("merged", None), ("bypass", Some(&overlay))] {
        let legacy = LegacyModel { cfg: &cfg, params: &backbone, overlay: ov };
        let plan = PlannedModel::resolve(&cfg, &backbone, ov, 1).unwrap();
        let mut sl = DecodeState::new(&cfg);
        let mut sp = DecodeState::new(&cfg);
        for (pos, &t) in toks.iter().enumerate() {
            let want = legacy.forward_step(t, &mut sl).unwrap();
            let got = plan.forward_step(t, &mut sp).unwrap();
            let diff = want
                .iter()
                .zip(&got)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(diff <= 1e-6, "{label} position {pos}: step diff {diff}");
        }
        assert_eq!(sl.len(), sp.len());
    }
}

/// Satellite property: threaded `matmul_nt` equals serial bitwise on odd
/// shapes — m, n, k drawn so they are NOT multiples of the thread count.
#[test]
fn prop_threaded_matmul_bitwise_on_odd_shapes() {
    prop_check(PropConfig { cases: 48, max_size: 23, base_seed: 0xF00D }, |rng, size| {
        let m = 1 + rng.below(size.max(1) * 2);
        let n = 1 + rng.below(size.max(1) * 2);
        let k = 1 + rng.below(size.max(1) * 2);
        let a = Tensor::randn(&[m, k], 1.0, rng);
        let b = Tensor::randn(&[n, k], 1.0, rng);
        let serial = matmul_nt(&a, &b);
        for threads in [2usize, 3, 5, 7, m + 1] {
            let par = matmul_nt_threaded(&a, &b, threads);
            if serial.data != par.data {
                return Err(format!("m={m} n={n} k={k} threads={threads}: not bitwise equal"));
            }
        }
        Ok(())
    })
    .unwrap();
}

/// Steady-state contract: a resolved plan keeps serving after the overlay
/// handle is gone (views are pre-bound), and re-threading does not change
/// results.
#[test]
fn plan_survives_overlay_drop_and_rethreading() {
    let (cfg, backbone) = nano();
    let deltas = synth_adapter(&cfg, &backbone, 1, 44).unwrap();
    let (tokens, pad, last) = batch_inputs(&cfg, 2);
    let plan = {
        let overlay = DeltaOverlay::new(&deltas);
        PlannedModel::resolve(&cfg, &backbone, Some(&overlay), 1).unwrap()
        // overlay dropped here; the plan's scatter views borrow `deltas`
    };
    assert_eq!(plan.bound_deltas(), deltas.len());
    let a = plan.lm_logits_at(&tokens, &pad, &last, 2).unwrap();
    let b = plan.with_threads(3).lm_logits_at(&tokens, &pad, &last, 2).unwrap();
    assert_eq!(a.data, b.data);
}
