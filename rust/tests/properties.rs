//! Property-based tests over the paper's invariants (DESIGN.md §6),
//! using the in-repo `testing::prop` mini-framework.

use neuroada::peft::optimizer::{AdamState, AdamW};
use neuroada::peft::selection::{select, select_topk, Strategy};
use neuroada::peft::DeltaStore;
use neuroada::tensor::Tensor;
use neuroada::testing::{prop_check, PropConfig};
use neuroada::util::rng::Rng;

fn cfgd() -> PropConfig {
    PropConfig { cases: 48, max_size: 24, base_seed: 0xBEEF }
}

/// Invariant 1: magnitude selection picks exactly the k largest |w| per row,
/// all rows covered, indices distinct & in range, descending order.
#[test]
fn prop_selection_is_topk() {
    prop_check(cfgd(), |rng, size| {
        let d_out = 1 + rng.below(size.max(1));
        let d_in = 2 + rng.below(size.max(1) + 2);
        let k = 1 + rng.below(d_in.min(5));
        let w = Tensor::randn(&[d_out, d_in], 1.0, rng);
        let sel = select_topk(&w, k);
        sel.check().map_err(|e| e.to_string())?;
        for i in 0..d_out {
            let row = w.row(i);
            let picked = sel.idx.row(i);
            let min_picked = picked.iter().map(|&j| row[j as usize].abs()).fold(f32::MAX, f32::min);
            for (j, v) in row.iter().enumerate() {
                if !picked.contains(&(j as i32)) && v.abs() > min_picked + 1e-9 {
                    return Err(format!("row {i}: missed larger |w| at {j}"));
                }
            }
            // descending
            let mags: Vec<f32> = picked.iter().map(|&j| row[j as usize].abs()).collect();
            if mags.windows(2).any(|m| m[0] < m[1] - 1e-9) {
                return Err(format!("row {i}: not descending"));
            }
        }
        Ok(())
    })
    .unwrap();
}

/// All four strategies produce structurally valid selections.
#[test]
fn prop_all_strategies_valid() {
    prop_check(cfgd(), |rng, size| {
        let d_out = 1 + rng.below(size.max(1));
        let d_in = 2 + rng.below(size.max(1) + 2);
        let k = 1 + rng.below(d_in.min(4));
        let w = Tensor::randn(&[d_out, d_in], 1.0, rng);
        let g = Tensor::randn(&[d_out, d_in], 1.0, rng);
        for s in [Strategy::Magnitude, Strategy::Gradient, Strategy::Reverse, Strategy::Random] {
            let sel = select(&w, k, s, Some(&g), rng);
            sel.check().map_err(|e| format!("{s:?}: {e}"))?;
        }
        Ok(())
    })
    .unwrap();
}

/// Invariant 2a: DeltaStore serialization round-trips exactly.
#[test]
fn prop_delta_roundtrip() {
    prop_check(cfgd(), |rng, size| {
        let d_out = 1 + rng.below(size.max(1));
        let d_in = 2 + rng.below(size.max(1) + 2);
        let k = 1 + rng.below(d_in.min(4));
        let w = Tensor::randn(&[d_out, d_in], 1.0, rng);
        let sel = select_topk(&w, k);
        let vals: Vec<f32> = (0..d_out * k).map(|_| rng.normal()).collect();
        let d = DeltaStore::from_f32(sel, &vals);
        let d2 = DeltaStore::from_bytes(&d.to_bytes()).map_err(|e| e)?;
        if d.theta_f32() != d2.theta_f32() || d.sel != d2.sel {
            return Err("roundtrip mismatch".into());
        }
        Ok(())
    })
    .unwrap();
}

/// Invariant 2b: merge(W, Δ) == W + dense(Δ), for any selection/values.
#[test]
fn prop_merge_equals_dense_add() {
    prop_check(cfgd(), |rng, size| {
        let d_out = 1 + rng.below(size.max(1));
        let d_in = 2 + rng.below(size.max(1) + 2);
        let k = 1 + rng.below(d_in.min(4));
        let mut w = Tensor::randn(&[d_out, d_in], 1.0, rng);
        let sel = select_topk(&w, k);
        let vals: Vec<f32> = (0..d_out * k).map(|_| rng.normal() * 0.1).collect();
        let d = DeltaStore::from_f32(sel, &vals);
        let mut expect = w.clone();
        expect.add_assign(&d.to_dense());
        d.merge_into(&mut w);
        if w.max_abs_diff(&expect) > 1e-6 {
            return Err(format!("merge err {}", w.max_abs_diff(&expect)));
        }
        Ok(())
    })
    .unwrap();
}

/// Invariant 4: sparse AdamW over the support == dense AdamW restricted to
/// the support (moments never leak across coordinates).
#[test]
fn prop_sparse_adamw_equals_dense_restriction() {
    prop_check(cfgd(), |rng, size| {
        let n_dense = 4 + rng.below(size.max(1) + 4);
        let n_sparse = 1 + rng.below(n_dense.min(6));
        let support = rng.sample_distinct(n_dense, n_sparse);
        let opt = AdamW { lr: 0.01, ..Default::default() };

        let mut dense_p = vec![0.0f32; n_dense];
        let mut dense_st = AdamState::new(n_dense);
        let mut sparse_p = vec![0.0f32; n_sparse];
        let mut sparse_st = AdamState::new(n_sparse);
        for _ in 0..5 {
            let g: Vec<f32> = (0..n_dense).map(|_| rng.normal()).collect();
            // dense: gradient masked to the support (mask-based method)
            let gm: Vec<f32> = (0..n_dense)
                .map(|i| if support.contains(&i) { g[i] } else { 0.0 })
                .collect();
            opt.step(&mut dense_p, &gm, &mut dense_st);
            // sparse: only the support coords exist (NeuroAda)
            let gs: Vec<f32> = support.iter().map(|&i| g[i]).collect();
            opt.step(&mut sparse_p, &gs, &mut sparse_st);
        }
        for (si, &di) in support.iter().enumerate() {
            if (sparse_p[si] - dense_p[di]).abs() > 1e-6 {
                return Err(format!("coord {di}: {} vs {}", sparse_p[si], dense_p[di]));
            }
        }
        // off-support must never move under the masked method
        for i in 0..n_dense {
            if !support.contains(&i) && dense_p[i] != 0.0 {
                return Err(format!("off-support coord {i} moved"));
            }
        }
        Ok(())
    })
    .unwrap();
}

/// Zero-θ bypass is an exact no-op on the forward (NeuroAda's init).
#[test]
fn prop_zero_delta_identity() {
    prop_check(cfgd(), |rng, size| {
        let d_out = 1 + rng.below(size.max(1));
        let d_in = 2 + rng.below(size.max(1) + 2);
        let k = 1 + rng.below(d_in.min(4));
        let mut w = Tensor::randn(&[d_out, d_in], 1.0, rng);
        let orig = w.clone();
        let sel = select_topk(&w, k);
        DeltaStore::zeros(sel).merge_into(&mut w);
        if w != orig {
            return Err("zero delta changed weights".into());
        }
        Ok(())
    })
    .unwrap();
}

/// Row-fraction masks enable exactly ⌈f·d_out⌉ whole rows.
#[test]
fn prop_row_fraction_mask() {
    prop_check(cfgd(), |rng, size| {
        let d_out = 1 + rng.below(size.max(1) + 2);
        let k = 1 + rng.below(3);
        let f = rng.f64();
        let m = neuroada::peft::selection::row_fraction_mask(d_out, k, f, rng);
        let want = ((f * d_out as f64).ceil() as usize).min(d_out);
        let mut on = 0;
        for i in 0..d_out {
            let row: Vec<f32> = (0..k).map(|j| m.at2(i, j)).collect();
            let all_on = row.iter().all(|&x| x == 1.0);
            let all_off = row.iter().all(|&x| x == 0.0);
            if !all_on && !all_off {
                return Err(format!("row {i} partially enabled"));
            }
            if all_on {
                on += 1;
            }
        }
        if on != want {
            return Err(format!("{on} rows on, want {want}"));
        }
        Ok(())
    })
    .unwrap();
}

/// bf16 quantization error of the delta store is bounded by BF16_EPS.
#[test]
fn prop_bf16_bounded_error() {
    prop_check(cfgd(), |rng, size| {
        let n = 1 + rng.below(size.max(1) + 4);
        let w = Tensor::randn(&[n, 4], 1.0, rng);
        let sel = select_topk(&w, 2);
        let vals: Vec<f32> = (0..n * 2).map(|_| rng.normal()).collect();
        let d = DeltaStore::from_f32(sel, &vals);
        for (a, b) in vals.iter().zip(d.theta_f32()) {
            if a.abs() > 1e-20 && ((a - b) / a).abs() > neuroada::tensor::bf16::BF16_EPS {
                return Err(format!("{a} -> {b}"));
            }
        }
        Ok(())
    })
    .unwrap();
}

/// Seeded random delta with a top-k selection over a fresh weight matrix.
fn rand_delta(rng: &mut Rng, d_out: usize, d_in: usize, k: usize) -> DeltaStore {
    let w = Tensor::randn(&[d_out, d_in], 1.0, rng);
    let sel = select_topk(&w, k);
    let vals: Vec<f32> = (0..d_out * k).map(|_| rng.normal() * 0.1).collect();
    DeltaStore::from_f32(sel, &vals)
}

/// Composition invariant (ISSUE-10): `weighted_union` is a function of the
/// part *multiset* — any permutation of the parts yields a bitwise-identical
/// store (checked via the exact checkpoint serialization). This is what lets
/// the serving stack canonicalize `"b+a"` and `"a+b"` to one identity.
#[test]
fn prop_weighted_union_is_order_independent_bitwise() {
    prop_check(cfgd(), |rng, size| {
        let d_out = 1 + rng.below(size.max(1));
        let d_in = 2 + rng.below(size.max(1) + 2);
        let n = 2 + rng.below(3);
        let parts: Vec<(f32, DeltaStore)> = (0..n)
            .map(|_| {
                let k = 1 + rng.below(d_in.min(4));
                let w = 0.05 + rng.below(20) as f32 * 0.1;
                (w, rand_delta(rng, d_out, d_in, k))
            })
            .collect();
        let fwd: Vec<(f32, &DeltaStore)> = parts.iter().map(|(w, d)| (*w, d)).collect();
        let base = DeltaStore::weighted_union(&fwd)?.to_bytes();
        let mut rev = fwd.clone();
        rev.reverse();
        let mut rot = fwd.clone();
        rot.rotate_left(1);
        for (tag, perm) in [("reversed", rev), ("rotated", rot)] {
            if DeltaStore::weighted_union(&perm)?.to_bytes() != base {
                return Err(format!("{tag} permutation changed the union bitwise"));
            }
        }
        Ok(())
    })
    .unwrap();
}

/// Composition invariant: a single part with weight exactly 1.0 is the
/// *bitwise* identity — same index order (not re-sorted), same bf16
/// payload, same serialization. Singles must survive composition untouched.
#[test]
fn prop_weighted_union_weight_one_single_part_is_identity() {
    prop_check(cfgd(), |rng, size| {
        let d_out = 1 + rng.below(size.max(1));
        let d_in = 2 + rng.below(size.max(1) + 2);
        let k = 1 + rng.below(d_in.min(4));
        let d = rand_delta(rng, d_out, d_in, k);
        let u = DeltaStore::weighted_union(&[(1.0, &d)])?;
        if u.to_bytes() != d.to_bytes() {
            return Err("weight-1.0 single part is not a bitwise identity".into());
        }
        Ok(())
    })
    .unwrap();
}

/// Composition invariant: overlapping indices sum *exactly* — two parts
/// sharing one selection (every index overlaps) produce, per slot, the f32
/// sum `wa·θa + wb·θb` rounded to BF16 exactly once.
#[test]
fn prop_weighted_union_overlapping_indices_sum_exactly() {
    use neuroada::tensor::bf16;
    prop_check(cfgd(), |rng, size| {
        let d_out = 1 + rng.below(size.max(1));
        let d_in = 2 + rng.below(size.max(1) + 2);
        let k = 1 + rng.below(d_in.min(4));
        let w = Tensor::randn(&[d_out, d_in], 1.0, rng);
        let sel = select_topk(&w, k);
        let vals = |rng: &mut Rng| -> Vec<f32> {
            (0..d_out * k).map(|_| rng.normal() * 0.1).collect()
        };
        let a = DeltaStore::from_f32(sel.clone(), &vals(rng));
        let b = DeltaStore::from_f32(sel, &vals(rng));
        let (wa, wb) = (0.6f32, 0.4f32);
        let u = DeltaStore::weighted_union(&[(wa, &a), (wb, &b)])?;
        let (da, db, du) = (a.to_dense(), b.to_dense(), u.to_dense());
        for t in 0..da.data.len() {
            let want = bf16::to_f32(bf16::to_bf16(wa * da.data[t] + wb * db.data[t]));
            if du.data[t].to_bits() != want.to_bits() {
                return Err(format!("slot {t}: {} != {want} (exact)", du.data[t]));
            }
        }
        Ok(())
    })
    .unwrap();
}

/// Composition invariant: a composed store's resident bytes match the
/// analytic `peft::memory` accounting the registry reports, and its union
/// width respects the `Σ kᵢ (capped at d_in)` bound.
#[test]
fn prop_composed_resident_bytes_match_memory_accounting() {
    use neuroada::peft::memory::{composed_k_bound, delta_resident_bytes};
    prop_check(cfgd(), |rng, size| {
        let d_out = 1 + rng.below(size.max(1));
        let d_in = 2 + rng.below(size.max(1) + 2);
        let n = 1 + rng.below(4);
        let parts: Vec<(f32, DeltaStore)> = (0..n)
            .map(|_| {
                let k = 1 + rng.below(d_in.min(4));
                (0.05 + rng.below(20) as f32 * 0.1, rand_delta(rng, d_out, d_in, k))
            })
            .collect();
        let refs: Vec<(f32, &DeltaStore)> = parts.iter().map(|(w, d)| (*w, d)).collect();
        let u = DeltaStore::weighted_union(&refs)?;
        let analytic = delta_resident_bytes(u.d_out() as u64, u.sel.d_in as u64, u.k() as u64);
        if analytic != u.storage_bytes() {
            return Err(format!("analytic {analytic} != measured {}", u.storage_bytes()));
        }
        let ks: Vec<u64> = parts.iter().map(|(_, d)| d.k() as u64).collect();
        let bound = composed_k_bound(&ks, d_in as u64);
        if (u.k() as u64) > bound {
            return Err(format!("union k {} exceeds bound {bound}", u.k()));
        }
        Ok(())
    })
    .unwrap();
}
