//! Property-based tests over the paper's invariants (DESIGN.md §6),
//! using the in-repo `testing::prop` mini-framework.

use neuroada::peft::optimizer::{AdamState, AdamW};
use neuroada::peft::selection::{select, select_topk, Strategy};
use neuroada::peft::DeltaStore;
use neuroada::tensor::Tensor;
use neuroada::testing::{prop_check, PropConfig};
use neuroada::util::rng::Rng;

fn cfgd() -> PropConfig {
    PropConfig { cases: 48, max_size: 24, base_seed: 0xBEEF }
}

/// Invariant 1: magnitude selection picks exactly the k largest |w| per row,
/// all rows covered, indices distinct & in range, descending order.
#[test]
fn prop_selection_is_topk() {
    prop_check(cfgd(), |rng, size| {
        let d_out = 1 + rng.below(size.max(1));
        let d_in = 2 + rng.below(size.max(1) + 2);
        let k = 1 + rng.below(d_in.min(5));
        let w = Tensor::randn(&[d_out, d_in], 1.0, rng);
        let sel = select_topk(&w, k);
        sel.check().map_err(|e| e.to_string())?;
        for i in 0..d_out {
            let row = w.row(i);
            let picked = sel.idx.row(i);
            let min_picked = picked.iter().map(|&j| row[j as usize].abs()).fold(f32::MAX, f32::min);
            for (j, v) in row.iter().enumerate() {
                if !picked.contains(&(j as i32)) && v.abs() > min_picked + 1e-9 {
                    return Err(format!("row {i}: missed larger |w| at {j}"));
                }
            }
            // descending
            let mags: Vec<f32> = picked.iter().map(|&j| row[j as usize].abs()).collect();
            if mags.windows(2).any(|m| m[0] < m[1] - 1e-9) {
                return Err(format!("row {i}: not descending"));
            }
        }
        Ok(())
    })
    .unwrap();
}

/// All four strategies produce structurally valid selections.
#[test]
fn prop_all_strategies_valid() {
    prop_check(cfgd(), |rng, size| {
        let d_out = 1 + rng.below(size.max(1));
        let d_in = 2 + rng.below(size.max(1) + 2);
        let k = 1 + rng.below(d_in.min(4));
        let w = Tensor::randn(&[d_out, d_in], 1.0, rng);
        let g = Tensor::randn(&[d_out, d_in], 1.0, rng);
        for s in [Strategy::Magnitude, Strategy::Gradient, Strategy::Reverse, Strategy::Random] {
            let sel = select(&w, k, s, Some(&g), rng);
            sel.check().map_err(|e| format!("{s:?}: {e}"))?;
        }
        Ok(())
    })
    .unwrap();
}

/// Invariant 2a: DeltaStore serialization round-trips exactly.
#[test]
fn prop_delta_roundtrip() {
    prop_check(cfgd(), |rng, size| {
        let d_out = 1 + rng.below(size.max(1));
        let d_in = 2 + rng.below(size.max(1) + 2);
        let k = 1 + rng.below(d_in.min(4));
        let w = Tensor::randn(&[d_out, d_in], 1.0, rng);
        let sel = select_topk(&w, k);
        let vals: Vec<f32> = (0..d_out * k).map(|_| rng.normal()).collect();
        let d = DeltaStore::from_f32(sel, &vals);
        let d2 = DeltaStore::from_bytes(&d.to_bytes()).map_err(|e| e)?;
        if d.theta_f32() != d2.theta_f32() || d.sel != d2.sel {
            return Err("roundtrip mismatch".into());
        }
        Ok(())
    })
    .unwrap();
}

/// Invariant 2b: merge(W, Δ) == W + dense(Δ), for any selection/values.
#[test]
fn prop_merge_equals_dense_add() {
    prop_check(cfgd(), |rng, size| {
        let d_out = 1 + rng.below(size.max(1));
        let d_in = 2 + rng.below(size.max(1) + 2);
        let k = 1 + rng.below(d_in.min(4));
        let mut w = Tensor::randn(&[d_out, d_in], 1.0, rng);
        let sel = select_topk(&w, k);
        let vals: Vec<f32> = (0..d_out * k).map(|_| rng.normal() * 0.1).collect();
        let d = DeltaStore::from_f32(sel, &vals);
        let mut expect = w.clone();
        expect.add_assign(&d.to_dense());
        d.merge_into(&mut w);
        if w.max_abs_diff(&expect) > 1e-6 {
            return Err(format!("merge err {}", w.max_abs_diff(&expect)));
        }
        Ok(())
    })
    .unwrap();
}

/// Invariant 4: sparse AdamW over the support == dense AdamW restricted to
/// the support (moments never leak across coordinates).
#[test]
fn prop_sparse_adamw_equals_dense_restriction() {
    prop_check(cfgd(), |rng, size| {
        let n_dense = 4 + rng.below(size.max(1) + 4);
        let n_sparse = 1 + rng.below(n_dense.min(6));
        let support = rng.sample_distinct(n_dense, n_sparse);
        let opt = AdamW { lr: 0.01, ..Default::default() };

        let mut dense_p = vec![0.0f32; n_dense];
        let mut dense_st = AdamState::new(n_dense);
        let mut sparse_p = vec![0.0f32; n_sparse];
        let mut sparse_st = AdamState::new(n_sparse);
        for _ in 0..5 {
            let g: Vec<f32> = (0..n_dense).map(|_| rng.normal()).collect();
            // dense: gradient masked to the support (mask-based method)
            let gm: Vec<f32> = (0..n_dense)
                .map(|i| if support.contains(&i) { g[i] } else { 0.0 })
                .collect();
            opt.step(&mut dense_p, &gm, &mut dense_st);
            // sparse: only the support coords exist (NeuroAda)
            let gs: Vec<f32> = support.iter().map(|&i| g[i]).collect();
            opt.step(&mut sparse_p, &gs, &mut sparse_st);
        }
        for (si, &di) in support.iter().enumerate() {
            if (sparse_p[si] - dense_p[di]).abs() > 1e-6 {
                return Err(format!("coord {di}: {} vs {}", sparse_p[si], dense_p[di]));
            }
        }
        // off-support must never move under the masked method
        for i in 0..n_dense {
            if !support.contains(&i) && dense_p[i] != 0.0 {
                return Err(format!("off-support coord {i} moved"));
            }
        }
        Ok(())
    })
    .unwrap();
}

/// Zero-θ bypass is an exact no-op on the forward (NeuroAda's init).
#[test]
fn prop_zero_delta_identity() {
    prop_check(cfgd(), |rng, size| {
        let d_out = 1 + rng.below(size.max(1));
        let d_in = 2 + rng.below(size.max(1) + 2);
        let k = 1 + rng.below(d_in.min(4));
        let mut w = Tensor::randn(&[d_out, d_in], 1.0, rng);
        let orig = w.clone();
        let sel = select_topk(&w, k);
        DeltaStore::zeros(sel).merge_into(&mut w);
        if w != orig {
            return Err("zero delta changed weights".into());
        }
        Ok(())
    })
    .unwrap();
}

/// Row-fraction masks enable exactly ⌈f·d_out⌉ whole rows.
#[test]
fn prop_row_fraction_mask() {
    prop_check(cfgd(), |rng, size| {
        let d_out = 1 + rng.below(size.max(1) + 2);
        let k = 1 + rng.below(3);
        let f = rng.f64();
        let m = neuroada::peft::selection::row_fraction_mask(d_out, k, f, rng);
        let want = ((f * d_out as f64).ceil() as usize).min(d_out);
        let mut on = 0;
        for i in 0..d_out {
            let row: Vec<f32> = (0..k).map(|j| m.at2(i, j)).collect();
            let all_on = row.iter().all(|&x| x == 1.0);
            let all_off = row.iter().all(|&x| x == 0.0);
            if !all_on && !all_off {
                return Err(format!("row {i} partially enabled"));
            }
            if all_on {
                on += 1;
            }
        }
        if on != want {
            return Err(format!("{on} rows on, want {want}"));
        }
        Ok(())
    })
    .unwrap();
}

/// bf16 quantization error of the delta store is bounded by BF16_EPS.
#[test]
fn prop_bf16_bounded_error() {
    prop_check(cfgd(), |rng, size| {
        let n = 1 + rng.below(size.max(1) + 4);
        let w = Tensor::randn(&[n, 4], 1.0, rng);
        let sel = select_topk(&w, 2);
        let vals: Vec<f32> = (0..n * 2).map(|_| rng.normal()).collect();
        let d = DeltaStore::from_f32(sel, &vals);
        for (a, b) in vals.iter().zip(d.theta_f32()) {
            if a.abs() > 1e-20 && ((a - b) / a).abs() > neuroada::tensor::bf16::BF16_EPS {
                return Err(format!("{a} -> {b}"));
            }
        }
        Ok(())
    })
    .unwrap();
}
