//! Serving-engine integration tests (artifact-free: everything runs through
//! the pure-rust forward, so these execute on any machine).
//!
//! Covers the ISSUE-1 acceptance points: ≥2 distinct adapters served from
//! one resident backbone, bypass-vs-merged logit parity to ≤1e-5, batch
//! coalescing under concurrent load, deadline flush, LRU eviction of merged
//! backbones, and hot-swap (register/evict while serving). ISSUE-2 adds
//! streaming-decode parity (KV-cached greedy continuation vs full
//! re-forward, merged AND bypass paths, token-for-token through the real
//! scheduler) and mid-flight decode-slot reuse without cross-contamination.
//! ISSUE-4 adds encoder classification serving: cls parity through the
//! full scheduler (queue → batcher → worker) against the offline host
//! encoder eval — merged and bypass, exact — plus mixed-adapter cls
//! coalescing. ISSUE-6 adds observability: a traced run must produce
//! stage spans covering ≥95% of every request's end-to-end latency, a
//! Chrome trace-event export, and Prometheus + JSON metrics that parse
//! back.

use neuroada::bench::serve_bench::synth_adapter;
use neuroada::config::presets;
use neuroada::data::{example_stream, tasks, Split};
use neuroada::eval::{eval_encoder_host, score};
use neuroada::model::init::init_params;
use neuroada::model::{greedy_full_reforward, merge_deltas, RefModel};
use neuroada::serve::scheduler::host_logits;
use neuroada::serve::{
    AdapterRegistry, Backend, ClsRequest, GenerateRequest, Reject, RegistryCfg, Request, ServeCfg,
    ServePath, Server,
};
use neuroada::util::rng::Rng;
use std::time::Duration;

fn nano() -> (neuroada::config::ModelCfg, neuroada::runtime::ValueStore) {
    let cfg = presets::model("nano").unwrap();
    let backbone = init_params(&cfg, &mut Rng::new(42));
    (cfg, backbone)
}

fn registry(n_adapters: usize, rcfg: RegistryCfg) -> AdapterRegistry {
    let (cfg, backbone) = nano();
    let reg = AdapterRegistry::new(cfg.clone(), backbone.clone(), rcfg);
    for i in 0..n_adapters {
        let deltas = synth_adapter(&cfg, &backbone, 1, 100 + i as u64).unwrap();
        reg.register(&format!("adapter-{i}"), deltas).unwrap();
    }
    reg
}

fn task_requests(cfg: &neuroada::config::ModelCfg, adapters: &[&str], n: usize) -> Vec<Request> {
    let task = tasks::by_name("cs-boolq").unwrap();
    let examples = example_stream(&task, Split::Test, 7, cfg.vocab, cfg.seq - 2, n);
    examples
        .into_iter()
        .enumerate()
        .map(|(i, ex)| Request {
            adapter: adapters[i % adapters.len()].to_string(),
            prompt: ex.prompt,
            options: ex.options,
        })
        .collect()
}

/// Acceptance: the unmerged bypass path and the merged-dense path produce
/// the same logits to ≤ 1e-5, end-to-end through the registry.
#[test]
fn bypass_matches_merged_to_tolerance() {
    let reg = registry(2, RegistryCfg { merged_capacity: 2, promote_after: 1, ..RegistryCfg::default() });
    let cfg = reg.model_cfg().clone();
    let reqs = task_requests(&cfg, &["adapter-0"], 4);
    let examples: Vec<neuroada::data::Example> = reqs
        .iter()
        .map(|r| neuroada::data::Example {
            prompt: r.prompt.clone(),
            answer_tok: 0,
            label: 0,
            options: r.options.clone(),
            score: 0.0,
        })
        .collect();
    let eb = neuroada::data::eval_batch(&examples, cfg.seq);
    for name in ["adapter-0", "adapter-1"] {
        let merged = reg.merge_now(name).unwrap();
        let bypass = reg.bypass(name).unwrap();
        let lm = host_logits(&cfg, &merged, &eb.tokens, &eb.pad_mask, &eb.last_pos, 4).unwrap();
        let lb = host_logits(&cfg, &bypass, &eb.tokens, &eb.pad_mask, &eb.last_pos, 4).unwrap();
        let diff = lm.max_abs_diff(&lb);
        assert!(diff <= 1e-5, "{name}: bypass vs merged diff {diff}");
    }
    // and the two adapters are genuinely distinct models
    let a = host_logits(&cfg, &reg.bypass("adapter-0").unwrap(), &eb.tokens, &eb.pad_mask, &eb.last_pos, 4).unwrap();
    let b = host_logits(&cfg, &reg.bypass("adapter-1").unwrap(), &eb.tokens, &eb.pad_mask, &eb.last_pos, 4).unwrap();
    assert!(a.max_abs_diff(&b) > 1e-6, "adapters should differ");
}

/// ≥2 distinct adapters served from one resident backbone, through the full
/// scheduler; every request answered; per-adapter accounting adds up.
#[test]
fn serves_multiple_adapters_from_one_backbone() {
    let reg = registry(3, RegistryCfg::default());
    let cfg = reg.model_cfg().clone();
    let srv = Server::start(reg, ServeCfg {
        max_batch: 4,
        max_queue: 128,
        max_delay: Duration::from_millis(5),
        workers: 2,
        ..ServeCfg::default()
    }, Backend::Host)
    .unwrap();
    let reqs = task_requests(&cfg, &["adapter-0", "adapter-1", "adapter-2"], 24);
    let responses = srv.serve_all(reqs);
    assert!(responses.iter().all(|r| r.is_ok()), "all requests served");
    let m = srv.shutdown();
    assert_eq!(m.served, 24);
    assert_eq!(m.adapters.len(), 3);
    for c in m.adapters.values() {
        assert_eq!(c.served, 8);
        assert_eq!(c.merged_hits + c.bypass_hits, c.served);
    }
}

/// Batch coalescing under concurrent load: many clients, few adapters —
/// the scheduler must execute far fewer batches than requests.
#[test]
fn coalesces_batches_under_concurrent_load() {
    let reg = registry(2, RegistryCfg::default());
    let cfg = reg.model_cfg().clone();
    let srv = Server::start(reg, ServeCfg {
        max_batch: 8,
        max_queue: 256,
        max_delay: Duration::from_millis(20),
        workers: 2,
        ..ServeCfg::default()
    }, Backend::Host)
    .unwrap();
    let reqs = task_requests(&cfg, &["adapter-0", "adapter-1"], 64);
    let (ok, rejected) = srv.drive_clients(reqs, 8);
    assert_eq!((ok, rejected), (64, 0));
    let m = srv.shutdown();
    assert_eq!(m.served, 64);
    assert!(
        m.batches < 64 && m.mean_batch > 1.0,
        "expected coalescing: {} batches, mean {}",
        m.batches,
        m.mean_batch
    );
}

/// Deadline flush: a lone request must be served within the flush window
/// (plus execution), not wait for a full batch that never arrives.
#[test]
fn deadline_flush_bounds_lone_request_latency() {
    let reg = registry(1, RegistryCfg::default());
    let cfg = reg.model_cfg().clone();
    let srv = Server::start(reg, ServeCfg {
        max_batch: 16,
        max_queue: 16,
        max_delay: Duration::from_millis(10),
        workers: 1,
        ..ServeCfg::default()
    }, Backend::Host)
    .unwrap();
    let req = task_requests(&cfg, &["adapter-0"], 1).remove(0);
    let resp = srv.submit(req).unwrap().wait().unwrap();
    assert_eq!(resp.batch_size, 1);
    // generous bound: 10ms flush + forward + scheduling noise on slow CI
    assert!(resp.latency < Duration::from_secs(10), "latency {:?}", resp.latency);
    srv.shutdown();
}

/// LRU eviction: with capacity 1 and instant promotion, the merged-copy
/// count never exceeds capacity while the deltas of every adapter stay
/// registered and servable.
#[test]
fn lru_keeps_merged_copies_within_capacity() {
    let reg = registry(3, RegistryCfg { merged_capacity: 1, promote_after: 1, ..RegistryCfg::default() });
    let cfg = reg.model_cfg().clone();
    let srv = Server::start(reg, ServeCfg {
        max_batch: 4,
        max_queue: 64,
        max_delay: Duration::from_millis(2),
        workers: 1,
        ..ServeCfg::default()
    }, Backend::Host)
    .unwrap();
    for round in 0..3 {
        let adapter = format!("adapter-{round}");
        let reqs = task_requests(&cfg, &[&adapter], 4);
        for r in srv.serve_all(reqs) {
            r.unwrap();
        }
        assert!(srv.registry().merged_count() <= 1, "capacity 1 exceeded");
        assert!(srv.registry().is_merged(&adapter), "{adapter} just promoted");
        assert_eq!(srv.registry().len(), 3, "deltas stay registered");
    }
    srv.shutdown();
}

/// Hot swap: adapters can be registered and evicted while the server runs;
/// evicted adapters reject with a typed error.
#[test]
fn hot_swap_register_and_evict_while_serving() {
    let reg = registry(1, RegistryCfg::default());
    let cfg = reg.model_cfg().clone();
    let (_, backbone) = nano();
    let srv = Server::start(reg, ServeCfg {
        max_batch: 4,
        max_queue: 64,
        max_delay: Duration::from_millis(2),
        workers: 1,
        ..ServeCfg::default()
    }, Backend::Host)
    .unwrap();
    // serve from the initial adapter
    let r = srv.serve_all(task_requests(&cfg, &["adapter-0"], 2));
    assert!(r.iter().all(|x| x.is_ok()));
    // hot-register a new adapter and serve from it immediately
    let deltas = synth_adapter(&cfg, &backbone, 1, 999).unwrap();
    srv.registry().register("late-arrival", deltas).unwrap();
    let r = srv.serve_all(task_requests(&cfg, &["late-arrival"], 2));
    assert!(r.iter().all(|x| x.is_ok()));
    // evict and observe the typed rejection
    assert!(srv.registry().evict("late-arrival"));
    match srv.submit(task_requests(&cfg, &["late-arrival"], 1).remove(0)) {
        Err(Reject::UnknownAdapter(a)) => assert_eq!(a, "late-arrival"),
        other => panic!("expected UnknownAdapter, got {:?}", other.map(|_| ())),
    }
    let m = srv.shutdown();
    assert_eq!(m.served, 4);
    assert_eq!(m.rejected.get("unknown_adapter"), Some(&1));
}

/// Acceptance (ISSUE-2): greedy continuation through the server's KV-cached
/// decode path matches the full re-forward continuation token-for-token,
/// on BOTH the merged and the bypass adapter paths.
#[test]
fn streaming_decode_parity_merged_and_bypass() {
    let (cfg, backbone) = nano();
    let deltas = synth_adapter(&cfg, &backbone, 1, 123).unwrap();
    let prompt: Vec<i32> = (0..6).map(|i| 4 + (i * 5) % 30).collect();
    let max_new = 8;
    // reference: full re-forward greedy continuation on the merged weights
    // (bypass parity with merged is covered by model-level tests)
    let reference = {
        let mut merged = backbone.clone();
        merge_deltas(&mut merged, &deltas).unwrap();
        greedy_full_reforward(&RefModel::new(&cfg, &merged), &prompt, max_new).unwrap()
    };
    for (rcfg, want_path) in [
        (RegistryCfg { merged_capacity: 2, promote_after: 1, ..RegistryCfg::default() }, ServePath::Merged),
        (RegistryCfg { merged_capacity: 0, promote_after: 1, ..RegistryCfg::default() }, ServePath::Bypass),
    ] {
        let reg = AdapterRegistry::new(cfg.clone(), backbone.clone(), rcfg);
        reg.register("gen-a", deltas.clone()).unwrap();
        let srv = Server::start(
            reg,
            ServeCfg { workers: 1, ..ServeCfg::default() },
            Backend::Host,
        )
        .unwrap();
        if want_path == ServePath::Merged {
            // the decode path never merges inline (it would stall every
            // active stream); promote explicitly to exercise the merged
            // decode path
            srv.registry().merge_now("gen-a").unwrap();
        }
        let r = srv
            .submit_generate(GenerateRequest {
                adapter: "gen-a".into(),
                prompt: prompt.clone(),
                max_new_tokens: max_new,
                stop: vec![],
                sample: None,
            })
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(r.path, want_path);
        assert_eq!(
            r.tokens, reference,
            "{want_path:?} served decode vs full re-forward reference"
        );
        let m = srv.shutdown();
        assert_eq!(m.gen_served, 1);
        assert_eq!(m.gen_tokens, max_new as u64);
    }
}

/// Satellite (ISSUE-2): a short sequence finishes while a long one is
/// decoding; the freed slot is reassigned mid-flight to the next queued
/// request, and no stream is cross-contaminated.
#[test]
fn mid_flight_slot_reuse_no_cross_contamination() {
    let (cfg, backbone) = nano();
    let deltas = synth_adapter(&cfg, &backbone, 1, 500).unwrap();
    let reg = AdapterRegistry::new(cfg.clone(), backbone.clone(), RegistryCfg::default());
    reg.register("gen-a", deltas.clone()).unwrap();
    let srv = Server::start(
        reg,
        ServeCfg { workers: 1, max_slots: 2, max_queue: 8, ..ServeCfg::default() },
        Backend::Host,
    )
    .unwrap();
    let prompt = |seed: i32| -> Vec<i32> { (0..6).map(|i| 4 + (i * 5 + seed * 3) % 30).collect() };
    let gen = |p: Vec<i32>, n: usize| GenerateRequest {
        adapter: "gen-a".into(),
        prompt: p,
        max_new_tokens: n,
        stop: vec![],
        sample: None,
    };
    // A holds a slot for 24 tokens; B finishes after 2 and frees its slot
    // while A is mid-flight; C (queued — only 2 slots) takes it over.
    let ta = srv.submit_generate(gen(prompt(0), 24)).unwrap();
    let tb = srv.submit_generate(gen(prompt(1), 2)).unwrap();
    let tc = srv.submit_generate(gen(prompt(2), 2)).unwrap();
    let ra = ta.wait().unwrap();
    let rb = tb.wait().unwrap();
    let rc = tc.wait().unwrap();
    // every stream matches its own single-request reference — slot reuse
    // must not leak KV state or tokens across sequences
    let mut merged = backbone.clone();
    merge_deltas(&mut merged, &deltas).unwrap();
    let m = RefModel::new(&cfg, &merged);
    assert_eq!(ra.tokens, greedy_full_reforward(&m, &prompt(0), 24).unwrap(), "A contaminated");
    assert_eq!(rb.tokens, greedy_full_reforward(&m, &prompt(1), 2).unwrap(), "B contaminated");
    assert_eq!(rc.tokens, greedy_full_reforward(&m, &prompt(2), 2).unwrap(), "C contaminated");
    // C completed while A was still decoding: the freed slot was reassigned
    // mid-flight (~20 decode steps before A's end), not after A drained.
    assert!(
        rc.latency < ra.latency,
        "C should finish in B's freed slot while A decodes (C {:?} vs A {:?})",
        rc.latency,
        ra.latency
    );
    let metrics = srv.shutdown();
    assert_eq!(metrics.gen_served, 3);
    assert_eq!(metrics.max_active_slots, 2, "both slots were occupied concurrently");
    assert_eq!(metrics.gen_tokens, 24 + 2 + 2);
}

/// Seeded encoder backbone: `init_params` zeroes the classifier head, so
/// randomize it (seeded) — otherwise every class logit is exactly 0 and
/// parity is vacuous.
fn enc_backbone(seed: u64) -> (neuroada::config::ModelCfg, neuroada::runtime::ValueStore) {
    let cfg = presets::model("enc-micro").unwrap();
    let mut backbone = init_params(&cfg, &mut Rng::new(seed));
    neuroada::bench::serve_bench::randomize_zero_head(&cfg, &mut backbone, seed ^ 0xC15).unwrap();
    (cfg, backbone)
}

/// Acceptance (ISSUE-4): encoder classification through the FULL scheduler
/// (queue → batcher → worker) reproduces the offline host encoder eval's
/// task metric EXACTLY, on both the merged and the bypass weight view. The
/// served batch assembly (`data::cls_batch`, padded to `cfg.seq`) and
/// prediction rule (NaN-safe argmax) are shared with `eval_encoder_host`,
/// so parity is bitwise, not to-tolerance.
#[test]
fn cls_serving_parity_merged_and_bypass_vs_eval_encoder() {
    let (cfg, backbone) = enc_backbone(42);
    let deltas = synth_adapter(&cfg, &backbone, 1, 321).unwrap();
    let task = tasks::by_name("glue-sst2").unwrap();
    let n = 24;
    let seed = 9;
    let examples = example_stream(&task, Split::Test, seed, cfg.vocab, cfg.seq, n);
    // offline oracles, one per weight view
    let mut merged_store = backbone.clone();
    merge_deltas(&mut merged_store, &deltas).unwrap();
    let oracle_merged = eval_encoder_host(&cfg, &merged_store, None, &task, n, seed, 1).unwrap();
    let oracle_bypass =
        eval_encoder_host(&cfg, &backbone, Some(&deltas), &task, n, seed, 1).unwrap();
    for (rcfg, want_path, oracle) in [
        (RegistryCfg { merged_capacity: 2, promote_after: 1, ..RegistryCfg::default() }, ServePath::Merged, oracle_merged),
        (RegistryCfg { merged_capacity: 0, promote_after: 1, ..RegistryCfg::default() }, ServePath::Bypass, oracle_bypass),
    ] {
        let reg = AdapterRegistry::new(cfg.clone(), backbone.clone(), rcfg);
        reg.register("enc-a", deltas.clone()).unwrap();
        let srv = Server::start(
            reg,
            ServeCfg {
                max_batch: 8,
                max_queue: 64,
                max_delay: Duration::from_millis(2),
                workers: 2,
                ..ServeCfg::default()
            },
            Backend::Host,
        )
        .unwrap();
        if want_path == ServePath::Merged {
            // promote up front: a batch racing an in-flight merge would
            // (correctly) ride the bypass, and this test pins the path
            srv.registry().merge_now("enc-a").unwrap();
        }
        let reqs: Vec<ClsRequest> =
            examples.iter().map(|ex| ClsRequest::from_example("enc-a", ex)).collect();
        let responses = srv.serve_all_cls(reqs);
        let mut preds = Vec::with_capacity(n);
        for r in responses {
            let r = r.expect("every cls request served");
            assert_eq!(r.path, want_path);
            assert_eq!(r.class_logits.len(), cfg.n_classes);
            preds.push(r.class);
        }
        let served_metric = score(&task, &examples, &preds);
        assert_eq!(served_metric, oracle, "{want_path:?} served cls metric vs eval_encoder_host");
        let m = srv.shutdown();
        assert_eq!(m.cls_served, n as u64);
        assert!(m.cls_latency.is_some());
    }
}

/// Satellite (ISSUE-4): mixed-adapter cls coalescing — two adapters'
/// requests interleaved through the shared queue still coalesce per
/// adapter, and every response matches its own adapter's offline
/// prediction (no cross-adapter contamination in the batcher).
#[test]
fn cls_mixed_adapter_coalescing_preserves_per_adapter_parity() {
    let (cfg, backbone) = enc_backbone(43);
    let deltas_a = synth_adapter(&cfg, &backbone, 1, 700).unwrap();
    let deltas_b = synth_adapter(&cfg, &backbone, 2, 800).unwrap();
    let reg = AdapterRegistry::new(
        cfg.clone(),
        backbone.clone(),
        RegistryCfg { merged_capacity: 0, promote_after: 1, ..RegistryCfg::default() },
    );
    reg.register("enc-a", deltas_a.clone()).unwrap();
    reg.register("enc-b", deltas_b.clone()).unwrap();
    let srv = Server::start(
        reg,
        ServeCfg {
            max_batch: 8,
            max_queue: 64,
            // long deadline: batches pop only when FULL, so coalescing is
            // deterministic once all requests are queued
            max_delay: Duration::from_secs(30),
            workers: 2,
            ..ServeCfg::default()
        },
        Backend::Host,
    )
    .unwrap();
    let task = tasks::by_name("glue-mnli").unwrap();
    let n = 32; // 16 per adapter = 2 full batches each
    let examples = example_stream(&task, Split::Test, 11, cfg.vocab, cfg.seq, n);
    // submit everything first (interleaved adapters), then wait
    let tickets: Vec<_> = examples
        .iter()
        .enumerate()
        .map(|(i, ex)| {
            let adapter = if i % 2 == 0 { "enc-a" } else { "enc-b" };
            srv.submit_cls(ClsRequest::from_example(adapter, ex)).unwrap()
        })
        .collect();
    // offline per-adapter predictions over the same examples
    let offline = |deltas: &[(String, neuroada::peft::DeltaStore)]| -> Vec<usize> {
        let overlay = neuroada::model::DeltaOverlay::new(deltas);
        let plan = neuroada::model::PlannedModel::resolve(
            &cfg,
            &backbone,
            Some(&overlay),
            &neuroada::tensor::pool::KernelPool::serial(),
        )
        .unwrap();
        examples
            .iter()
            .map(|ex| {
                let cb = neuroada::data::cls_batch(std::slice::from_ref(ex), cfg.seq);
                plan.cls_predict(&cb.tokens, &cb.pad_mask, 1).unwrap().1[0]
            })
            .collect()
    };
    let (preds_a, preds_b) = (offline(&deltas_a), offline(&deltas_b));
    for (i, t) in tickets.into_iter().enumerate() {
        let r = t.wait().expect("served");
        let want = if i % 2 == 0 { preds_a[i] } else { preds_b[i] };
        assert_eq!(r.class, want, "request {i} contaminated");
        assert!(r.batch_size > 1, "request {i} rode a coalesced batch");
    }
    let m = srv.shutdown();
    assert_eq!(m.cls_served, n as u64);
    assert!(
        m.cls_batches < n && m.cls_mean_batch > 1.0,
        "expected cls coalescing: {} batches, mean {}",
        m.cls_batches,
        m.cls_mean_batch
    );
    assert_eq!(m.adapters["enc-a"].bypass_hits, (n / 2) as u64);
    assert_eq!(m.adapters["enc-b"].bypass_hits, (n / 2) as u64);
}

/// Tentpole (ISSUE-6): end-to-end observability through the full server.
/// A traced run (scoring + one streamed generation) must (a) record stage
/// spans covering ≥95% of every request's end-to-end latency, (b) serve
/// Prometheus text and a JSON snapshot over HTTP that parse back with
/// stage and kernel-pool fields, and (c) export a valid Chrome
/// trace-event JSON.
#[test]
fn traced_serving_covers_latency_and_exports_parse() {
    use neuroada::obs::trace::{request_coverage, Stage};
    use neuroada::util::json::Json;

    let reg = registry(2, RegistryCfg { merged_capacity: 2, promote_after: 1, ..RegistryCfg::default() });
    let cfg = reg.model_cfg().clone();
    let srv = Server::start(
        reg,
        ServeCfg {
            max_batch: 4,
            max_queue: 64,
            max_delay: Duration::from_millis(2),
            workers: 2,
            trace: true,
            ..ServeCfg::default()
        },
        Backend::Host,
    )
    .unwrap();
    let http = srv.metrics_http("127.0.0.1:0").unwrap();

    // scoring traffic plus one streamed generation, all traced
    let reqs = task_requests(&cfg, &["adapter-0", "adapter-1"], 12);
    let (ok, rejected) = srv.drive_clients(reqs, 3);
    assert_eq!((ok, rejected), (12, 0));
    srv.submit_generate(GenerateRequest {
        adapter: "adapter-0".into(),
        prompt: (0..6).map(|i| 4 + i).collect(),
        max_new_tokens: 4,
        stop: vec![],
        sample: None,
    })
    .unwrap()
    .wait()
    .unwrap();

    // live scrape while the server is still up
    let prom = neuroada::obs::http::get(http.addr(), "/metrics").unwrap();
    assert!(prom.contains("neuroada_requests_served_total 12"), "prometheus text:\n{prom}");
    assert!(prom.contains("neuroada_stage_seconds{stage=\"queue_wait\""), "{prom}");
    assert!(prom.contains("neuroada_pool_threads"), "{prom}");
    let snap = neuroada::obs::http::get(http.addr(), "/metrics.json").unwrap();
    let j = Json::parse(&snap).expect("metrics.json parses back");
    assert_eq!(j.at(&["served"]).and_then(|v| v.as_usize()), Some(12));
    assert!(j.at(&["stages", "forward", "p50"]).and_then(|v| v.as_f64()).is_some());
    assert!(j.at(&["pool", "threads"]).and_then(|v| v.as_usize()).is_some());
    http.stop();

    // the coverage contract: spans account for ≥95% of each request's
    // end-to-end (Request-span) latency — the stage taxonomy is contiguous,
    // so anything below that means an instrumentation gap
    let tracer = srv.tracer();
    let events = tracer.events();
    assert_eq!(tracer.dropped(), 0, "ring should not wrap at this load");
    for st in [Stage::QueueWait, Stage::Forward, Stage::Prefill, Stage::DecodeStream] {
        assert!(events.iter().any(|e| e.stage == st), "missing {st:?} spans");
    }
    let cov = request_coverage(&events);
    assert_eq!(cov.len(), 13, "12 scored + 1 generation");
    for (id, frac) in &cov {
        assert!(*frac >= 0.95, "request {id}: stage coverage {frac}");
    }

    // Chrome trace export: complete-span ("X") events in valid JSON
    let chrome = tracer.to_chrome_json();
    let parsed = Json::parse(&chrome.dump()).expect("chrome trace parses back");
    let evs = parsed.at(&["traceEvents"]).and_then(|v| v.as_arr()).expect("traceEvents array");
    assert!(!evs.is_empty());
    assert_eq!(evs[0].at(&["ph"]).and_then(|v| v.as_str()), Some("X"));

    let m = srv.shutdown();
    assert!(m.pool_busy_frac.is_some(), "traced run times the kernel pool");
    assert!(m.stage(neuroada::serve::metrics::StageLat::Forward).is_some());
}
