//! E2E parity oracle for adapter composition (the ISSUE-10 tentpole).
//!
//! Serving a weighted mixture spec online (`"task-a:0.5+task-b:0.5"`) must
//! be **bitwise** equal to serving the same mixture composed offline
//! (`neuroada compose`) and registered as an ordinary adapter — on the Host
//! backend, on BOTH the merged and the bypass weight view, across scoring,
//! KV-cached greedy decode, and encoder classification. Both paths run
//! `peft::compose_deltas` with the parts in canonical spec order and round
//! to BF16 exactly once, which is what makes the equality exact rather than
//! to-tolerance.

use neuroada::bench::serve_bench::{randomize_zero_head, synth_adapter};
use neuroada::config::presets;
use neuroada::data::{example_stream, tasks, Split};
use neuroada::model::init::init_params;
use neuroada::model::{greedy_full_reforward, merge_deltas, RefModel};
use neuroada::peft::{compose_deltas, DeltaStore};
use neuroada::serve::{
    AdapterRegistry, AdapterSpec, Backend, ClsRequest, GenerateRequest, RegistryCfg, Request,
    ServeCfg, ServePath, Server,
};
use neuroada::util::rng::Rng;

type Deltas = Vec<(String, DeltaStore)>;

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// The two registered parts and their offline composition — exactly what
/// `neuroada compose --spec "task-a:0.5+task-b:0.5" --out-name blend`
/// writes: `compose_deltas` over the parts in canonical (name-sorted)
/// spec order.
fn mixture_parts(
    cfg: &neuroada::config::ModelCfg,
    backbone: &neuroada::runtime::ValueStore,
) -> (AdapterSpec, Deltas, Deltas, Deltas) {
    let spec = AdapterSpec::parse("task-a:0.5+task-b:0.5").unwrap();
    // canonical form is name-sorted with normalized weights; the uniform
    // spelling and a swapped spelling intern to the SAME identity
    assert_eq!(spec.key(), "task-a:0.5+task-b:0.5");
    assert_eq!(AdapterSpec::parse("task-a+task-b").unwrap().key(), spec.key());
    assert_eq!(AdapterSpec::parse("task-b:0.5+task-a:0.5").unwrap().key(), spec.key());
    let da = synth_adapter(cfg, backbone, 1, 151).unwrap();
    let db = synth_adapter(cfg, backbone, 2, 252).unwrap();
    let composed = compose_deltas(&[(0.5, da.as_slice()), (0.5, db.as_slice())]).unwrap();
    (spec, da, db, composed)
}

fn path_cfgs() -> [(RegistryCfg, ServePath); 2] {
    [
        (
            RegistryCfg { merged_capacity: 2, promote_after: 1, ..RegistryCfg::default() },
            ServePath::Merged,
        ),
        (
            RegistryCfg { merged_capacity: 0, promote_after: 1, ..RegistryCfg::default() },
            ServePath::Bypass,
        ),
    ]
}

/// Compose (if composite) and force-promote both identities so the test
/// pins the merged path — scoring traffic racing an in-flight merge would
/// (correctly) ride the bypass.
fn pin_merged(srv: &Server, spec: &AdapterSpec) {
    srv.registry().resolve_spec(spec).expect("mixture composes");
    srv.registry().merge_now(spec.key()).unwrap();
    srv.registry().merge_now("blend").unwrap();
}

/// Acceptance: scoring and KV-cached greedy decode under the online
/// mixture spec are bitwise equal to the offline-composed adapter, on the
/// merged and the bypass path.
#[test]
fn online_mixture_bitwise_equals_composed_adapter_score_and_generate() {
    let cfg = presets::model("nano").unwrap();
    let backbone = init_params(&cfg, &mut Rng::new(42));
    let (spec, da, db, composed) = mixture_parts(&cfg, &backbone);

    // ground truth for the decode tokens: full re-forward greedy
    // continuation on the composed mixture merged into the backbone
    let prompt: Vec<i32> = (0..6).map(|i| 4 + (i * 5) % 30).collect();
    let max_new = 8;
    let reference = {
        let mut merged = backbone.clone();
        merge_deltas(&mut merged, &composed).unwrap();
        greedy_full_reforward(&RefModel::new(&cfg, &merged), &prompt, max_new).unwrap()
    };

    let task = tasks::by_name("cs-boolq").unwrap();
    let examples = example_stream(&task, Split::Test, 7, cfg.vocab, cfg.seq - 2, 3);

    for (rcfg, want_path) in path_cfgs() {
        let reg = AdapterRegistry::new(cfg.clone(), backbone.clone(), rcfg);
        reg.register("task-a", da.clone()).unwrap();
        reg.register("task-b", db.clone()).unwrap();
        reg.register("blend", composed.clone()).unwrap();
        let srv =
            Server::start(reg, ServeCfg { workers: 1, ..ServeCfg::default() }, Backend::Host)
                .unwrap();
        if want_path == ServePath::Merged {
            pin_merged(&srv, &spec);
        }
        // scoring: the same prompt+options under the mixture spec (both
        // spellings) and under the composed adapter, one request per batch
        // so batch assembly is identical
        for (i, ex) in examples.iter().enumerate() {
            let score = |adapter: &str| {
                let r = srv
                    .submit(Request {
                        adapter: adapter.to_string(),
                        prompt: ex.prompt.clone(),
                        options: ex.options.clone(),
                    })
                    .unwrap()
                    .wait()
                    .unwrap();
                assert_eq!(r.path, want_path, "{adapter:?}");
                assert!(r.option_logits.iter().all(|x| x.is_finite()), "{adapter:?}: NaN/inf");
                r
            };
            let online = score(spec.key());
            let offline = score("blend");
            assert_eq!(
                bits(&online.option_logits),
                bits(&offline.option_logits),
                "{want_path:?} example {i}: online mixture vs composed adapter must be bitwise"
            );
            assert_eq!(online.pick, offline.pick);
            if i == 0 {
                // a swapped spelling canonicalizes to the same identity
                let swapped = score("task-b:0.5+task-a:0.5");
                assert_eq!(bits(&swapped.option_logits), bits(&offline.option_logits));
            }
        }
        // KV-cached greedy decode, token for token
        let gen = |adapter: &str| {
            let r = srv
                .submit_generate(GenerateRequest {
                    adapter: adapter.to_string(),
                    prompt: prompt.clone(),
                    max_new_tokens: max_new,
                    stop: vec![],
                    sample: None,
                })
                .unwrap()
                .wait()
                .unwrap();
            assert_eq!(r.path, want_path, "{adapter:?}");
            r.tokens
        };
        let online = gen(spec.key());
        let offline = gen("blend");
        assert_eq!(online, offline, "{want_path:?}: decode tokens");
        if want_path == ServePath::Merged {
            // the server's merged copy is built by the same merge the
            // reference used, so this leg is exact too
            assert_eq!(online, reference, "merged decode vs full re-forward reference");
        }
        let m = srv.shutdown();
        assert_eq!(m.rejected.values().sum::<u64>(), 0, "no composite request rejected");
    }
}

/// Acceptance: encoder classification under the online mixture spec is
/// bitwise equal (class logits) to the offline-composed adapter, merged
/// and bypass.
#[test]
fn online_mixture_bitwise_equals_composed_adapter_cls() {
    let cfg = presets::model("enc-micro").unwrap();
    let mut backbone = init_params(&cfg, &mut Rng::new(42));
    // init_params zeroes the classifier head; randomize it (seeded) so
    // parity is not vacuously 0 == 0
    randomize_zero_head(&cfg, &mut backbone, 42 ^ 0xC15).unwrap();
    let (spec, da, db, composed) = mixture_parts(&cfg, &backbone);
    let task = tasks::by_name("glue-sst2").unwrap();
    let examples = example_stream(&task, Split::Test, 9, cfg.vocab, cfg.seq, 8);

    for (rcfg, want_path) in path_cfgs() {
        let reg = AdapterRegistry::new(cfg.clone(), backbone.clone(), rcfg);
        reg.register("task-a", da.clone()).unwrap();
        reg.register("task-b", db.clone()).unwrap();
        reg.register("blend", composed.clone()).unwrap();
        let srv =
            Server::start(reg, ServeCfg { workers: 1, ..ServeCfg::default() }, Backend::Host)
                .unwrap();
        if want_path == ServePath::Merged {
            pin_merged(&srv, &spec);
        }
        for (i, ex) in examples.iter().enumerate() {
            let cls = |adapter: &str| {
                let r = srv
                    .submit_cls(ClsRequest::from_example(adapter, ex))
                    .unwrap()
                    .wait()
                    .unwrap();
                assert_eq!(r.path, want_path, "{adapter:?}");
                assert!(r.class_logits.iter().all(|x| x.is_finite()), "{adapter:?}: NaN/inf");
                r
            };
            let online = cls(spec.key());
            let offline = cls("blend");
            assert_eq!(
                bits(&online.class_logits),
                bits(&offline.class_logits),
                "{want_path:?} example {i}: online mixture vs composed adapter must be bitwise"
            );
            assert_eq!(online.class, offline.class);
        }
        srv.shutdown();
    }
}
