//! Online adapter lifecycle e2e (ISSUE 9) — artifact-free: the host
//! hill-climb trainer and the host eval oracles run the pure-rust forward,
//! so the full **train → select → register → serve** loop executes on any
//! machine.
//!
//! Acceptance points covered here:
//! * a winning candidate is PROMOTED with a versioned atomic cutover
//!   (`name@vN`) while the server is actively serving traffic — afterwards
//!   the served bypass view is bit-identical to the candidate's checkpoint
//!   (no stale or half-merged weights);
//! * a losing candidate (fault-injected via `HostTrainer::corrupt`) is
//!   ROLLED BACK: the version does not move and the incumbent's delta
//!   bytes are untouched;
//! * every lifecycle stage shows up in the `ServeMetrics` event counters.
//!
//! The A/B verdict is *measured*, so each test pins its outcome down by
//! measuring first: [`find_seed`] dry-runs the (deterministic) trainer
//! across seeds until one satisfies the wanted relation on that seed's
//! held-out slice, then the real job reproduces it through the server.

use neuroada::config::presets;
use neuroada::config::ModelCfg;
use neuroada::data::tasks;
use neuroada::lifecycle::{objective, HostTrainer, JobSpec, LifecycleManager, Trainer};
use neuroada::model::init::init_params;
use neuroada::peft::DeltaStore;
use neuroada::runtime::ValueStore;
use neuroada::serve::{AdapterRegistry, Backend, ModelRef, RegistryCfg, Request, ServeCfg, Server};
use neuroada::train::checkpoint;
use neuroada::util::rng::Rng;
use std::collections::BTreeMap;
use std::path::PathBuf;

fn nano() -> (ModelCfg, ValueStore) {
    let cfg = presets::model("nano").unwrap();
    let backbone = init_params(&cfg, &mut Rng::new(42));
    (cfg, backbone)
}

fn server(cfg: &ModelCfg, backbone: &ValueStore) -> Server {
    let reg = AdapterRegistry::new(cfg.clone(), backbone.clone(), RegistryCfg::default());
    Server::start(reg, ServeCfg { max_batch: 4, workers: 2, ..ServeCfg::default() }, Backend::Host)
        .unwrap()
}

fn spec(seed: u64, steps: usize) -> JobSpec {
    JobSpec {
        name: "svc".into(),
        task: "cs-boolq".into(),
        k: 1,
        budget: 0,
        steps,
        seed,
        eval_examples: 16,
    }
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("neuroada-lc-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Candidate deltas exactly as `Trainer::train` would produce them for
/// this spec (the trainer is deterministic in the spec seed), plus their
/// metric on the spec's held-out A/B slice.
fn dry_run(
    trainer: &Trainer,
    cfg: &ModelCfg,
    backbone: &ValueStore,
    s: &JobSpec,
) -> (Vec<(String, DeltaStore)>, f64) {
    let task = tasks::by_name(&s.task).unwrap();
    let cand = trainer.train("nano", cfg, backbone, &task, s, 1).unwrap();
    let m = objective(cfg, backbone, Some(&cand.deltas), &task, s.eval_examples, s.seed ^ 0xABE7, 1)
        .unwrap();
    (cand.deltas, m)
}

/// Find a seed whose candidate's held-out metric satisfies `accept(cand,
/// incumbent)` against `reference` (`None` = the bare backbone). Panics if
/// 32 seeds can't produce one — that would mean the A/B can no longer
/// distinguish models at all.
fn find_seed(
    trainer: &Trainer,
    cfg: &ModelCfg,
    backbone: &ValueStore,
    steps: usize,
    reference: Option<&[(String, DeltaStore)]>,
    accept: impl Fn(f64, f64) -> bool,
    what: &str,
) -> u64 {
    let task = tasks::by_name("cs-boolq").unwrap();
    for seed in 1000..1032 {
        let s = spec(seed, steps);
        let (_, cand) = dry_run(trainer, cfg, backbone, &s);
        let inc =
            objective(cfg, backbone, reference, &task, s.eval_examples, seed ^ 0xABE7, 1).unwrap();
        if accept(cand, inc) {
            return seed;
        }
    }
    panic!("no seed in 1000..1032 gives a candidate that {what}");
}

fn bypass_bytes(srv: &Server, name: &str) -> BTreeMap<String, Vec<u8>> {
    match srv.registry().bypass(name).unwrap() {
        ModelRef::Bypass { deltas, .. } => {
            deltas.iter().map(|(n, d)| (n.clone(), d.to_bytes())).collect()
        }
        _ => panic!("bypass() must return the bypass view"),
    }
}

fn delta_map(deltas: &[(String, DeltaStore)]) -> BTreeMap<String, Vec<u8>> {
    deltas.iter().map(|(n, d)| (n.clone(), d.to_bytes())).collect()
}

fn traffic(cfg: &ModelCfg, name: &str, n: usize) -> Vec<Request> {
    let task = tasks::by_name("cs-boolq").unwrap();
    let mut rng = Rng::new(0x7AFF1C);
    (0..n)
        .map(|_| {
            let ex = (task.gen)(&mut rng, cfg.vocab, cfg.seq - 2);
            Request { adapter: name.into(), prompt: ex.prompt, options: ex.options }
        })
        .collect()
}

/// Win path: a fresh-name job registers `svc@v1`; a later job whose
/// candidate measurably beats the (deliberately regressed) incumbent cuts
/// over to the next version atomically — WHILE concurrent clients hammer
/// the adapter through the scheduler. After the cutover the served bypass
/// view is bit-identical to the promoted checkpoint: nothing stale,
/// nothing half-merged, and no request errored across the swap.
#[test]
fn winning_candidate_promotes_with_versioned_cutover_under_traffic() {
    let (cfg, backbone) = nano();
    let srv = server(&cfg, &backbone);
    let good = Trainer::Host(HostTrainer { slice: 8, ..HostTrainer::default() });
    let bad = Trainer::Host(HostTrainer { corrupt: 2.0, ..HostTrainer::default() });
    // the hill-climb starts at θ=0 (≡ backbone) and is monotone on its
    // TRAIN slice; on the held-out slice an accepted step could still
    // regress, so pin a seed that ties-or-beats the backbone (a tie
    // promotes a first registration)
    let seed1 =
        find_seed(&good, &cfg, &backbone, 4, None, |c, i| c >= i, "ties-or-beats the backbone");

    let mut mgr = LifecycleManager::new("nano", cfg.clone(), backbone.clone(), good);
    mgr.out_dir = Some(tmp_dir("win"));

    // job 1: fresh name → v1 is born
    let out1 = mgr.run_job(&srv, &spec(seed1, 4)).unwrap();
    assert!(out1.promoted, "fresh-name tie-or-win must register");
    assert_eq!(out1.version, Some(1));
    assert_eq!(srv.registry().version("svc"), Some(1));
    // the served bypass view IS the checkpoint that was just emitted
    let ckpt = checkpoint::load_deltas(out1.artifact_dir.as_ref().unwrap()).unwrap();
    assert_eq!(bypass_bytes(&srv, "svc"), delta_map(&ckpt), "served view != emitted checkpoint");

    // regress the incumbent in place (simulates a bad earlier promote):
    // corrupted deltas that measurably LOSE to the bare backbone — which
    // is exactly what a steps=0 candidate is
    let seed2 = find_seed(&bad, &cfg, &backbone, 0, None, |c, i| c < i, "loses to the backbone");
    let (bad_deltas, _) = dry_run(&bad, &cfg, &backbone, &spec(seed2, 0));
    srv.swap_adapter("svc", bad_deltas).unwrap();
    assert_eq!(srv.registry().version("svc"), Some(2), "manual regression bumped to v2");

    // job 2: steps=0 candidate (≡ backbone) strictly beats the corrupted
    // incumbent → versioned cutover to v3, with clients in flight
    let zero = Trainer::Host(HostTrainer { corrupt: 0.0, slice: 8, ..HostTrainer::default() });
    let (expect_deltas, _) = dry_run(&zero, &cfg, &backbone, &spec(seed2, 0));
    let mgr2 = {
        let mut m = LifecycleManager::new("nano", cfg.clone(), backbone.clone(), zero);
        m.out_dir = Some(tmp_dir("win2"));
        m
    };
    let reqs = traffic(&cfg, "svc", 48);
    let (out2, ok, rejected) = std::thread::scope(|s| {
        let h = s.spawn(|| srv.drive_clients(reqs, 3));
        let out2 = mgr2.run_job(&srv, &spec(seed2, 0)).unwrap();
        let (ok, rejected) = h.join().unwrap();
        (out2, ok, rejected)
    });
    assert!(out2.promoted, "cand {:.3} vs inc {:.3}", out2.candidate_metric, out2.incumbent_metric);
    assert!(out2.candidate_metric > out2.incumbent_metric);
    assert_eq!(out2.version, Some(3), "cutover is versioned");
    assert_eq!(srv.registry().version("svc"), Some(3));
    assert_eq!(ok + rejected, 48, "every in-flight request got a definite answer");
    assert_eq!(rejected, 0, "no request errored across the cutover");

    // no stale / half-merged weights: the served view now matches the
    // winning candidate exactly, and the emitted checkpoint agrees
    assert_eq!(bypass_bytes(&srv, "svc"), delta_map(&expect_deltas));
    let ckpt2 = checkpoint::load_deltas(out2.artifact_dir.as_ref().unwrap()).unwrap();
    assert_eq!(delta_map(&ckpt2), delta_map(&expect_deltas));

    let report = srv.shutdown();
    assert_eq!(report.lifecycle.get("train"), Some(&2));
    assert_eq!(report.lifecycle.get("ab_eval"), Some(&2));
    assert_eq!(report.lifecycle.get("promote"), Some(&2));
    assert!(report.lifecycle.get("rollback").is_none());
    let _ = std::fs::remove_dir_all(mgr.out_dir.unwrap());
    let _ = std::fs::remove_dir_all(mgr2.out_dir.unwrap());
}

/// Rollback path: a corrupted candidate loses its A/B against both a bare
/// backbone (fresh name → nothing gets registered) and a live incumbent
/// (the version does not move, the incumbent's bytes are untouched, and
/// the loser's checkpoint artifact is still kept as evidence).
#[test]
fn losing_candidate_rolls_back_and_incumbent_survives() {
    let (cfg, backbone) = nano();
    let srv = server(&cfg, &backbone);
    let good = Trainer::Host(HostTrainer { slice: 8, ..HostTrainer::default() });
    let bad = Trainer::Host(HostTrainer { corrupt: 2.0, ..HostTrainer::default() });

    // fresh name, losing candidate: nothing is registered at all
    let seed_fresh =
        find_seed(&bad, &cfg, &backbone, 0, None, |c, i| c < i, "loses to the backbone");
    let mut sab = LifecycleManager::new("nano", cfg.clone(), backbone.clone(), bad);
    sab.out_dir = Some(tmp_dir("lose"));
    let out = sab.run_job(&srv, &spec(seed_fresh, 0)).unwrap();
    assert!(!out.promoted);
    assert_eq!(out.version, None);
    assert!(!srv.registry().contains("svc"), "rollback on a fresh name must not register");
    // ...but the artifact is kept as evidence
    assert!(out.artifact_dir.as_ref().unwrap().join("deltas").is_dir());

    // install a real incumbent, then throw a corrupted candidate at it
    let seed1 =
        find_seed(&good, &cfg, &backbone, 4, None, |c, i| c >= i, "ties-or-beats the backbone");
    let mut mgr = LifecycleManager::new("nano", cfg.clone(), backbone.clone(), good);
    mgr.out_dir = Some(tmp_dir("lose2"));
    let out1 = mgr.run_job(&srv, &spec(seed1, 4)).unwrap();
    assert!(out1.promoted);
    let before = bypass_bytes(&srv, "svc");
    let incumbent: Vec<(String, DeltaStore)> = match srv.registry().bypass("svc").unwrap() {
        ModelRef::Bypass { deltas, .. } => deltas.as_ref().clone(),
        _ => panic!("bypass() must return the bypass view"),
    };

    // pin the corrupt seed against the *actual* incumbent this time
    let bad = Trainer::Host(HostTrainer { corrupt: 2.0, ..HostTrainer::default() });
    let seed2 = find_seed(
        &bad,
        &cfg,
        &backbone,
        0,
        Some(&incumbent),
        |c, i| c < i,
        "loses to the incumbent",
    );
    let out2 = sab.run_job(&srv, &spec(seed2, 0)).unwrap();
    assert!(
        !out2.promoted,
        "cand {:.3} vs inc {:.3}",
        out2.candidate_metric,
        out2.incumbent_metric
    );
    assert!(out2.candidate_metric < out2.incumbent_metric);
    assert_eq!(out2.version, None);
    assert_eq!(srv.registry().version("svc"), Some(1), "rollback must not move the version");
    assert_eq!(bypass_bytes(&srv, "svc"), before, "incumbent bytes must be untouched");

    srv.shutdown();
    let _ = std::fs::remove_dir_all(sab.out_dir.unwrap());
    let _ = std::fs::remove_dir_all(mgr.out_dir.unwrap());
}

/// The budget knob flows end-to-end: a budgeted job's promoted deltas have
/// per-projection k_p shaped by `budget_plan` (some projections squeezed
/// below the uniform k), and the job still promotes on a fresh name.
#[test]
fn budgeted_job_promotes_with_shaped_deltas() {
    let (cfg, backbone) = nano();
    let srv = server(&cfg, &backbone);
    let trainer = Trainer::Host(HostTrainer { slice: 8, ..HostTrainer::default() });
    let plan = neuroada::lifecycle::budget_plan(&cfg, &backbone, 2, 512).unwrap().unwrap();

    let mgr = LifecycleManager::new("nano", cfg.clone(), backbone.clone(), trainer);
    // steps=0 keeps the candidate at θ=0 ≡ the backbone: a deterministic
    // tie, which promotes a first registration — this test is about the
    // budget SHAPE, not training quality
    let mut s = spec(21, 0);
    s.k = 2;
    s.budget = 512;
    let out = mgr.run_job(&srv, &s).unwrap();
    assert!(out.promoted, "fresh-name tie must register");

    let served = match srv.registry().bypass("svc").unwrap() {
        ModelRef::Bypass { deltas, .. } => deltas,
        _ => panic!("bypass() must return the bypass view"),
    };
    // every served projection's k matches the plan, and the plan squeezed
    // at least one projection below the uniform k (the budget actually bit:
    // nano at k=2 uniform would cost 2304 params, over the 512 budget)
    for (name, d) in served.iter() {
        assert_eq!(d.sel.k, plan[name], "{name}: served k != planned k_p");
    }
    assert!(served.iter().any(|(_, d)| d.sel.k < 2), "budget 512 should squeeze some projection");
    srv.shutdown();
}
