//! Cross-layer integration tests over the real AOT artifacts.
//!
//! These exercise the full stack: rust selection/state → HLO train/eval
//! artifacts on PJRT → merge → rust reference model. They are the
//! executable form of DESIGN.md §6's invariants 2/3/5/6.

use neuroada::config::presets;
use neuroada::data::{lm_batch, tasks};
use neuroada::eval::merged_params;
use neuroada::model::init::init_params;
use neuroada::model::RefModel;
use neuroada::peft::{MethodKind, Strategy};
use neuroada::runtime::{state::run_once, Engine, Manifest, Value, ValueStore};
use neuroada::train::{build_session, setup::extract_deltas, Schedule};
use neuroada::util::rng::Rng;

fn manifest() -> Option<Manifest> {
    Manifest::load("artifacts").ok()
}

fn pattern_batch(cfg: &neuroada::config::ModelCfg, seed: u64) -> Vec<(String, Value)> {
    let task = tasks::by_name("cs-boolq").unwrap();
    let mut rng = Rng::new(seed);
    let examples: Vec<_> = (0..cfg.batch)
        .map(|_| (task.gen)(&mut rng, cfg.vocab, cfg.seq - 2))
        .collect();
    let b = lm_batch(&examples, cfg.seq);
    vec![
        ("batch.tokens".to_string(), Value::I32 { shape: vec![cfg.batch, cfg.seq], data: b.tokens }),
        ("batch.targets".to_string(), Value::I32 { shape: vec![cfg.batch, cfg.seq], data: b.targets }),
        ("batch.loss_mask".to_string(), Value::F32 { shape: vec![cfg.batch, cfg.seq], data: b.loss_mask }),
        ("batch.pad_mask".to_string(), Value::F32 { shape: vec![cfg.batch, cfg.seq], data: b.pad_mask }),
    ]
}

/// Invariant: the rust reference transformer and the HLO eval artifact
/// compute the same forward (strongest cross-layer parity signal).
#[test]
fn ref_model_matches_hlo_eval() {
    let Some(m) = manifest() else { return };
    let engine = Engine::shared();
    let meta = m.get("nano_eval").unwrap();
    let cfg = presets::model("nano").unwrap();
    let mut rng = Rng::new(3);
    let params = init_params(&cfg, &mut rng);

    let b = cfg.batch;
    let tokens: Vec<i32> = (0..b * cfg.seq).map(|i| 4 + (i as i32 * 7) % 200).collect();
    let pad: Vec<f32> = vec![1.0; b * cfg.seq];
    let last: Vec<i32> = (0..b).map(|i| (i % cfg.seq) as i32).collect();

    // HLO path
    let mut store = params.clone();
    for (name, d_out, _) in cfg.proj_shapes() {
        store.insert_f32(format!("biases.{name}"), &[d_out], vec![0.0; d_out]);
    }
    store.insert_i32("tokens", &[b, cfg.seq], tokens.clone());
    store.insert_f32("pad_mask", &[b, cfg.seq], pad.clone());
    store.insert_i32("last_pos", &[b], last.clone());
    let out = run_once(&engine, meta, &store).unwrap();
    let hlo_logits = out.get(&meta.outputs[0].name).unwrap().as_f32().unwrap();

    // rust reference path
    let rm = RefModel::new(&cfg, &params);
    let ref_logits = rm.lm_logits_at(&tokens, &pad, &last, b).unwrap();

    let mut max_err = 0f32;
    for (a, r) in hlo_logits.iter().zip(&ref_logits.data) {
        max_err = max_err.max((a - r).abs());
    }
    assert!(max_err < 5e-3, "parity max err {max_err}");
}

/// Invariant 3: NeuroAda and mask-based sparse tuning, given the same
/// support and LR, follow the SAME loss trajectory through the real
/// artifacts.
#[test]
fn neuroada_equals_masked_through_artifacts() {
    let Some(m) = manifest() else { return };
    let engine = Engine::shared();
    let cfg = presets::model("nano").unwrap();
    let mut rng = Rng::new(5);
    let params = init_params(&cfg, &mut rng);

    let mut run = |method: MethodKind, artifact: &str| -> Vec<f32> {
        let meta = m.get(artifact).unwrap();
        let mut rng = Rng::new(6);
        let mut setup = build_session(
            &engine, meta, &params, method, Strategy::Magnitude, 1.0, None, &mut rng,
        )
        .unwrap();
        let mut losses = Vec::new();
        for t in 0..8 {
            let batch = pattern_batch(&cfg, 100 + t);
            losses.push(setup.session.step(&engine, &batch, 5e-3).unwrap());
        }
        losses
    };
    let na = run(MethodKind::NeuroAda { k: 1 }, "nano_neuroada_k1");
    let mk = run(MethodKind::Masked { k: 1 }, "nano_masked");
    for (a, b) in na.iter().zip(&mk) {
        assert!((a - b).abs() < 2e-4, "trajectories diverged: {na:?} vs {mk:?}");
    }
}

/// Invariant 2: merged-weights forward == bypass forward (Algorithm 1
/// Phase 3 has zero behavioural cost), verified through the artifacts.
#[test]
fn merge_equivalence_through_artifacts() {
    let Some(m) = manifest() else { return };
    let engine = Engine::shared();
    let cfg = presets::model("nano").unwrap();
    let mut rng = Rng::new(7);
    let params = init_params(&cfg, &mut rng);
    let meta = m.get("nano_neuroada_k4").unwrap();
    let mut setup = build_session(
        &engine, meta, &params, MethodKind::NeuroAda { k: 4 },
        Strategy::Magnitude, 1.0, None, &mut rng,
    )
    .unwrap();
    for t in 0..5 {
        let batch = pattern_batch(&cfg, 200 + t);
        setup.session.step(&engine, &batch, 1e-2).unwrap();
    }
    let deltas = extract_deltas(&setup.session, &setup.selections).unwrap();
    assert!(deltas.iter().any(|(_, d)| d.theta_f32().iter().any(|&x| x != 0.0)));
    let (merged, _) = merged_params(&setup.session, MethodKind::NeuroAda { k: 4 }, &deltas).unwrap();

    // loss of a fresh frozen session on merged params == loss of the
    // trained bypass session on the same batch.
    // Use the full method with zero deltas as a "frozen forward" probe.
    let full_meta = m.get("nano_full").unwrap();
    let mut frozen = build_session(
        &engine, full_meta, &merged, MethodKind::Full, Strategy::Magnitude, 1.0, None,
        &mut Rng::new(1),
    )
    .unwrap();
    let batch = pattern_batch(&cfg, 999);
    // lr=0 → loss computed, no movement
    let merged_loss = frozen.session.step(&engine, &batch, 0.0).unwrap();
    let bypass_loss = setup.session.step(&engine, &batch, 0.0).unwrap();
    // bf16 round-trip of θ in extract_deltas costs ~1e-3 relative
    assert!(
        (merged_loss - bypass_loss).abs() < 3e-2 * bypass_loss.abs().max(1.0),
        "merged {merged_loss} vs bypass {bypass_loss}"
    );
}

/// Invariant 6: analytic memory model matches what the session actually
/// holds, for the state classes rust controls.
#[test]
fn memory_model_matches_session() {
    let Some(m) = manifest() else { return };
    let engine = Engine::shared();
    let cfg = presets::model("nano").unwrap();
    let mut rng = Rng::new(9);
    let params = init_params(&cfg, &mut rng);
    for (method, artifact) in [
        (MethodKind::NeuroAda { k: 1 }, "nano_neuroada_k1"),
        (MethodKind::Masked { k: 1 }, "nano_masked"),
        (MethodKind::Full, "nano_full"),
    ] {
        let meta = m.get(artifact).unwrap();
        let setup = build_session(
            &engine, meta, &params, method, Strategy::Magnitude, 1.0, None, &mut rng,
        )
        .unwrap();
        let analytic = neuroada::peft::Method::new(
            method, cfg.projections(), cfg.backbone_params(),
        )
        .memory(neuroada::peft::memory::DtypeModel::F32);
        // measured mutable state = trainable + m + v (f32)
        let measured = setup.session.state_bytes();
        let expected = analytic.trainable_params + 2 * analytic.optimizer / 2; // trainable + m+v
        let expected = expected; // trainable(f32) + optimizer(m+v f32)
        let want = analytic.trainable_params + analytic.optimizer;
        let _ = expected;
        assert_eq!(measured, want, "{}", method.name());
    }
}

/// Property: selection through the whole stack stays within budget — the
/// number of trainable θ the artifact expects equals rows × k.
#[test]
fn trainable_budget_matches_manifest() {
    let Some(m) = manifest() else { return };
    for (name, k) in [("nano_neuroada_k1", 1usize), ("nano_neuroada_k4", 4)] {
        let meta = m.get(name).unwrap();
        let cfg = presets::model("nano").unwrap();
        let rows: usize = cfg.proj_shapes().iter().map(|(_, o, _)| o).sum();
        assert_eq!(meta.trainable_params, rows * k);
    }
}

/// The Fig. 6 row-fraction mask really freezes neurons through the artifact.
#[test]
fn slot_mask_freezes_rows_through_artifact() {
    let Some(m) = manifest() else { return };
    let engine = Engine::shared();
    let cfg = presets::model("nano").unwrap();
    let mut rng = Rng::new(11);
    let params = init_params(&cfg, &mut rng);
    let meta = m.get("nano_neuroada_k1").unwrap();
    let mut setup = build_session(
        &engine, meta, &params, MethodKind::NeuroAda { k: 1 },
        Strategy::Magnitude, 0.5, None, &mut rng,
    )
    .unwrap();
    for t in 0..4 {
        let batch = pattern_batch(&cfg, 300 + t);
        setup.session.step(&engine, &batch, 1e-2).unwrap();
    }
    // every projection: exactly the masked rows stayed at 0
    let mut frozen_rows = 0usize;
    let mut moved_rows = 0usize;
    for (name, _sel) in &setup.selections {
        let mask = setup.session.store.get(&format!("aux.slot_mask.{name}")).unwrap();
        let th = setup.session.store.get(&format!("trainable.body.{name}")).unwrap();
        for (mv, tv) in mask.as_f32().unwrap().iter().zip(th.as_f32().unwrap()) {
            if *mv == 0.0 {
                assert_eq!(*tv, 0.0, "{name}: frozen slot moved");
                frozen_rows += 1;
            } else if *tv != 0.0 {
                moved_rows += 1;
            }
        }
    }
    assert!(frozen_rows > 0 && moved_rows > 0);
}
