"""Selection kernel spec tests (Eq. 2 / Fig. 7 strategies).

The ordering/tie-break spec here is shared with rust `peft::selection`; the
golden vectors in tests/golden/ are cross-checked by `cargo test` too.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, topk

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


@given(st.integers(1, 60), st.integers(1, 60), st.integers(1, 8), st.integers(0, 9999))
def test_topk_pallas_matches_ref(d_out, d_in, k, seed):
    k = min(k, d_in)
    w = jax.random.normal(jax.random.PRNGKey(seed), (d_out, d_in), jnp.float32)
    idx, vals = topk.topk_rows_pallas(w, k)
    want = ref.topk_rows(w, k)
    np.testing.assert_array_equal(idx, want)
    np.testing.assert_allclose(vals, jnp.abs(w)[jnp.arange(d_out)[:, None], idx], rtol=1e-6)


@given(st.integers(2, 50), st.integers(2, 50), st.integers(1, 6), st.integers(0, 9999))
def test_topk_invariants(d_out, d_in, k, seed):
    """(1) indices in range & distinct per row; (2) selected magnitudes
    dominate unselected; (3) descending order within a row."""
    k = min(k, d_in)
    w = np.asarray(jax.random.normal(jax.random.PRNGKey(seed), (d_out, d_in)))
    idx = np.asarray(ref.topk_rows(jnp.asarray(w), k))
    aw = np.abs(w)
    for i in range(d_out):
        row = idx[i]
        assert len(set(row.tolist())) == k
        assert (row >= 0).all() and (row < d_in).all()
        sel = aw[i, row]
        assert (np.diff(sel) <= 1e-12).all(), "not descending"
        unsel = np.delete(aw[i], row)
        if len(unsel):
            assert sel.min() >= unsel.max() - 1e-12


def test_tie_break_lower_index():
    w = jnp.array([[2.0, -2.0, 2.0, 1.0]], jnp.float32)
    idx = ref.topk_rows(w, 3)
    np.testing.assert_array_equal(np.asarray(idx), [[0, 1, 2]])
    idx_p, _ = topk.topk_rows_pallas(w, 3)
    np.testing.assert_array_equal(np.asarray(idx_p), [[0, 1, 2]])


def test_strategies():
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (20, 30), jnp.float32)
    k = 3
    mag = topk.select(w, k, "magnitude")
    np.testing.assert_array_equal(mag, ref.topk_rows(w, k))

    rev = np.asarray(topk.select(w, k, "reverse"))
    aw = np.abs(np.asarray(w))
    for i in range(20):
        sel = aw[i, rev[i]]
        unsel = np.delete(aw[i], rev[i])
        assert sel.max() <= unsel.min() + 1e-12

    grads = jax.random.normal(jax.random.PRNGKey(1), w.shape)
    gsel = topk.select(w, k, "gradient", grads=grads)
    np.testing.assert_array_equal(gsel, ref.topk_rows(grads, k))

    rnd = np.asarray(topk.select(w, k, "random", key=jax.random.PRNGKey(2)))
    for i in range(20):
        assert len(set(rnd[i].tolist())) == k
        assert (rnd[i] >= 0).all() and (rnd[i] < 30).all()


def test_every_neuron_gets_a_slot():
    """The paper's core design goal: every neuron (row) has ≥1 trainable
    bypass — selection always returns a full [d_out, k] index matrix."""
    w = jnp.zeros((17, 5), jnp.float32)  # even degenerate all-zero weights
    idx, _ = topk.topk_rows_pallas(w, 1)
    assert idx.shape == (17, 1)
