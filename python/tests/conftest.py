import os
import sys

# Tests are run from the python/ directory (see Makefile); make that robust
# when pytest is invoked from the repo root too.
_HERE = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _HERE not in sys.path:
    sys.path.insert(0, _HERE)
