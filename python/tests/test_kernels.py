"""L1 correctness: Pallas kernels vs the pure-jnp oracles in kernels/ref.py.

Hypothesis sweeps shapes/k/batch (and dtypes) — the system prompt's core
correctness signal for the kernel layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import neuroada as na
from compile.kernels import ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _mk(seed, b, d_in, d_out, k, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(k1, (b, d_in), dtype)
    w = jax.random.normal(k2, (d_out, d_in), dtype)
    idx = ref.topk_rows(w, k)
    th = jax.random.normal(k3, (d_out, k), dtype) * 0.1
    return x, w, idx, th


shape_st = st.tuples(
    st.integers(1, 9),    # batch
    st.integers(2, 40),   # d_in
    st.integers(1, 40),   # d_out
)


@given(shape_st, st.integers(1, 4), st.integers(0, 10_000))
def test_fwd_pallas_matches_ref(shape, k, seed):
    b, d_in, d_out = shape
    k = min(k, d_in)
    x, w, idx, th = _mk(seed, b, d_in, d_out, k)
    got = na.sparse_delta_matmul_pallas(x, w, idx, th)
    want = ref.sparse_delta_matmul(x, w, idx, th)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@given(shape_st, st.integers(1, 4), st.integers(0, 10_000))
def test_fwd_jnp_matches_ref(shape, k, seed):
    b, d_in, d_out = shape
    k = min(k, d_in)
    x, w, idx, th = _mk(seed, b, d_in, d_out, k)
    got = na.sparse_delta_matmul_jnp(x, w, idx, th)
    want = ref.sparse_delta_matmul(x, w, idx, th)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@given(shape_st, st.integers(1, 4), st.integers(0, 10_000))
def test_bwd_pallas_matches_ref(shape, k, seed):
    b, d_in, d_out = shape
    k = min(k, d_in)
    x, w, idx, th = _mk(seed, b, d_in, d_out, k)
    g = jax.random.normal(jax.random.PRNGKey(seed + 1), (b, d_out), jnp.float32)
    dx_want, dth_want = ref.sparse_delta_grads(x, w, idx, th, g)
    dx = na.sparse_delta_dx_pallas(g, w, idx, th)
    dth = na.sparse_delta_dtheta_pallas(x, idx, g)
    np.testing.assert_allclose(dx, dx_want, rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(dth, dth_want, rtol=3e-5, atol=3e-5)


def test_blocked_grid_padding():
    """Shapes that do NOT divide the block sizes exercise the pad/slice path
    and multi-step grids."""
    x, w, idx, th = _mk(0, 130, 50, 300, 2)
    got = na.sparse_delta_matmul_pallas(x, w, idx, th, block_b=32, block_r=64)
    want = ref.sparse_delta_matmul(x, w, idx, th)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)
    g = jax.random.normal(jax.random.PRNGKey(9), (130, 300), jnp.float32)
    dx = na.sparse_delta_dx_pallas(g, w, idx, th, block_b=32, block_r=64)
    dth = na.sparse_delta_dtheta_pallas(x, idx, g, block_r=64)
    dx_want, dth_want = ref.sparse_delta_grads(x, w, idx, th, g)
    np.testing.assert_allclose(dx, dx_want, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(dth, dth_want, rtol=1e-4, atol=1e-4)


def test_custom_vjp_matches_autodiff_of_oracle():
    x, w, idx, th = _mk(3, 6, 20, 15, 2)

    def f_pallas(xx, tt):
        return (na._neuroada_linear_pallas(xx, w, idx, tt) ** 2).sum()

    def f_ref(xx, tt):
        return (ref.sparse_delta_matmul(xx, w, idx, tt) ** 2).sum()

    gx_p, gt_p = jax.grad(f_pallas, argnums=(0, 1))(x, th)
    gx_r, gt_r = jax.grad(f_ref, argnums=(0, 1))(x, th)
    np.testing.assert_allclose(gx_p, gx_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gt_p, gt_r, rtol=1e-4, atol=1e-4)


def test_duplicate_indices_accumulate():
    """Spec: duplicate idx entries sum their θ contributions (scatter-add)."""
    x = jnp.ones((2, 4), jnp.float32)
    w = jnp.zeros((3, 4), jnp.float32)
    idx = jnp.array([[1, 1], [0, 2], [3, 3]], jnp.int32)
    th = jnp.array([[1.0, 2.0], [3.0, 4.0], [5.0, -5.0]], jnp.float32)
    want = ref.sparse_delta_matmul(x, w, idx, th)
    got = na.sparse_delta_matmul_pallas(x, w, idx, th)
    np.testing.assert_allclose(got, want, atol=1e-6)
    np.testing.assert_allclose(got[:, 0], 3.0)  # 1+2
    np.testing.assert_allclose(got[:, 2], 0.0)  # 5-5


def test_leading_dims_flattened():
    """neuroada_linear accepts [..., d_in] activations (B, T, d)."""
    x, w, idx, th = _mk(5, 6, 16, 12, 2)
    x3 = x.reshape(2, 3, 16)
    y = na.neuroada_linear(x3, w, idx, th, impl="jnp")
    assert y.shape == (2, 3, 12)
    np.testing.assert_allclose(
        y.reshape(6, 12), ref.sparse_delta_matmul(x, w, idx, th), rtol=2e-5, atol=2e-5
    )


def test_zero_theta_is_identity():
    """θ=0 (the init) must reproduce the frozen forward exactly — NeuroAda
    starts finetuning from the pretrained model's behaviour."""
    x, w, idx, _ = _mk(7, 4, 24, 18, 3)
    th0 = jnp.zeros((18, 3), jnp.float32)
    for impl in ("jnp", "pallas"):
        y = na.neuroada_linear(x, w, idx, th0, impl=impl)
        np.testing.assert_allclose(y, x @ w.T, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtypes(dtype):
    x, w, idx, th = _mk(11, 4, 12, 10, 2, dtype)
    got = na.sparse_delta_matmul_pallas(x, w, idx, th)
    want = ref.sparse_delta_matmul(x, w, idx, th)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )
