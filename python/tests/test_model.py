"""L2 correctness: model graphs, PEFT method semantics, AdamW, merge.

Key invariants (DESIGN.md §6):
  3. neuroada ≡ masked trajectories under identical selection/LR/init.
  2. merged-weights forward == delta forward.
  4. sparse AdamW == dense AdamW restricted to the support.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import ref

CFG = M.SIZES["nano"]


def _pattern_batch(cfg):
    toks = ((jnp.arange(cfg.seq)[None, :] * 3 + jnp.arange(cfg.batch)[:, None]) % 11 + 3).astype(
        jnp.int32
    )
    return {
        "tokens": toks,
        "targets": jnp.roll(toks, -1, 1),
        "loss_mask": jnp.ones(toks.shape, jnp.float32),
        "pad_mask": jnp.ones(toks.shape, jnp.float32),
    }


def _run(method, steps, k=2, lr=5e-3, seed=0, init_fn=None, cfg=CFG):
    step, ex = M.make_train_step(cfg, method, k=k)
    params, tr, m, v, aux, batch, _, _ = ex(jax.random.PRNGKey(seed))
    if method == "neuroada":
        for n in cfg.proj_shapes():
            aux["idx"][n] = ref.topk_rows(params[n], k)
    if method == "masked":
        for n in cfg.proj_shapes():
            idx = ref.topk_rows(params[n], k)
            aux["mask"][n] = ref.scatter_delta_dense(
                params[n].shape, idx, jnp.ones_like(idx, jnp.float32)
            )
    if init_fn:
        tr = init_fn(tr)
    b = _pattern_batch(cfg)
    js = jax.jit(
        lambda tr, mm, vv, tt: step(params, tr, mm, vv, aux, b, jnp.float32(lr), tt)
    )
    losses = []
    for i in range(steps):
        out = js(tr, m, v, jnp.float32(i + 1))
        tr, m, v = out["trainable"], out["m"], out["v"]
        losses.append(float(out["loss"]))
    return losses, tr, params, aux


def test_neuroada_equals_masked_trajectory():
    """Invariant 3: with the same support, the two methods are the same
    optimization — the paper's memory comparison is apples-to-apples."""
    ln, trn, _, _ = _run("neuroada", 15)
    lm, trm, _, _ = _run("masked", 15)
    np.testing.assert_allclose(ln, lm, rtol=1e-5, atol=1e-5)


def test_neuroada_trajectory_matches_dense_delta_restricted():
    """The θ values after training equal the masked method's dense delta
    values gathered at the selected coordinates."""
    _, trn, params, aux = _run("neuroada", 10)
    _, trm, _, _ = _run("masked", 10)
    for n in CFG.proj_shapes():
        idx = np.asarray(aux["idx"][n])
        dense = np.asarray(trm["body"][n])
        rows = np.arange(dense.shape[0])[:, None]
        np.testing.assert_allclose(
            np.asarray(trn["body"][n]), dense[rows, idx], rtol=1e-4, atol=1e-5
        )


def test_masked_never_updates_off_support():
    _, trm, params, _ = _run("masked", 10)
    step, ex = M.make_train_step(CFG, "masked")
    _, _, _, _, aux, _, _, _ = ex(jax.random.PRNGKey(0))
    for n in CFG.proj_shapes():
        idx = ref.topk_rows(params[n], 2)
        mask = np.asarray(
            ref.scatter_delta_dense(params[n].shape, idx, jnp.ones((params[n].shape[0], 2)))
        )
        dense = np.asarray(trm["body"][n])
        assert np.abs(dense * (1 - np.minimum(mask, 1))).max() == 0.0


def test_merge_equivalence():
    """Invariant 2 / Algorithm 1 phase 3: zero inference overhead."""
    _, tr, params, aux = _run("neuroada", 12)
    merged = dict(params)
    for n in CFG.proj_shapes():
        merged[n] = ref.merge(params[n], aux["idx"][n], tr["body"][n])
    b = _pattern_batch(CFG)
    y_delta = M.lm_logits(CFG, params, M.make_adapt("neuroada", tr["body"], aux), b["tokens"], b["pad_mask"])
    y_merged = M.lm_logits(CFG, merged, M.make_adapt("frozen", None, {}), b["tokens"], b["pad_mask"])
    np.testing.assert_allclose(y_delta, y_merged, rtol=1e-3, atol=2e-3)


def test_slot_mask_freezes_rows():
    """Fig. 6 machinery: rows with slot_mask=0 must keep θ=0 forever."""
    step, ex = M.make_train_step(CFG, "neuroada", k=2)
    params, tr, m, v, aux, batch, _, _ = ex(jax.random.PRNGKey(0))
    for n in CFG.proj_shapes():
        aux["idx"][n] = ref.topk_rows(params[n], 2)
        sm = np.ones(aux["slot_mask"][n].shape, np.float32)
        sm[:: 2] = 0.0  # freeze every other neuron
        aux["slot_mask"][n] = jnp.asarray(sm)
    b = _pattern_batch(CFG)
    js = jax.jit(lambda tr, mm, vv, tt: step(params, tr, mm, vv, aux, b, jnp.float32(5e-3), tt))
    for i in range(5):
        out = js(tr, m, v, jnp.float32(i + 1))
        tr, m, v = out["trainable"], out["m"], out["v"]
    for n in CFG.proj_shapes():
        th = np.asarray(tr["body"][n])
        assert np.abs(th[::2]).max() == 0.0
        assert np.abs(th[1::2]).max() > 0.0


def test_adamw_matches_dense_restriction():
    """Invariant 4: sparse AdamW over [d_out,k] leaves == dense AdamW
    restricted to the support (bias correction included)."""
    key = jax.random.PRNGKey(4)
    g1 = jax.random.normal(key, (6, 3))
    p = jnp.zeros((6, 3))
    m = jnp.zeros_like(p)
    v = jnp.zeros_like(p)
    lr = 1e-2
    p1, m1, v1 = M.adamw_update(p, g1, m, v, lr, 1.0)
    # manual dense AdamW
    mm = 0.1 * np.asarray(g1)
    vv = 0.001 * np.asarray(g1) ** 2
    mhat = mm / (1 - 0.9)
    vhat = vv / (1 - 0.999)
    want = -lr * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(p1, want, rtol=1e-5, atol=1e-7)


def test_lora_learns_with_proper_init():
    def init(tr):
        body = dict(tr["body"])
        for n in list(body):
            if n.endswith(".A"):
                body[n] = jax.random.normal(jax.random.PRNGKey(hash(n) % 2**31), body[n].shape) * 0.02
        return {"body": body}

    losses, _, _, _ = _run("lora", 40, lr=1e-2, init_fn=init)
    assert losses[-1] < losses[0] - 0.2


def test_all_methods_reduce_loss():
    for method in ("neuroada", "masked", "full", "bitfit"):
        losses, _, _, _ = _run(method, 40, lr=1e-2)
        assert losses[-1] < losses[0] - 0.2, f"{method}: {losses[0]} -> {losses[-1]}"


def test_pretrain_learns_pattern():
    cfg = CFG
    step, ex = M.make_train_step(cfg, "pretrain")
    params, m, v, _, _ = ex()
    b = _pattern_batch(cfg)
    js = jax.jit(lambda p, mm, vv, tt: step(p, mm, vv, b, jnp.float32(3e-3), tt))
    first = last = None
    for i in range(150):
        out = js(params, m, v, jnp.float32(i + 1))
        params, m, v = out["params"], out["m"], out["v"]
        if i == 0:
            first = float(out["loss"])
        last = float(out["loss"])
    assert last < first * 0.55, f"{first} -> {last}"


def test_encoder_classifier_step():
    cfg = M.SIZES["enc-micro"]
    step, ex = M.make_train_step(cfg, "neuroada", k=1)
    params, tr, m, v, aux, batch, _, _ = ex(jax.random.PRNGKey(0))
    for n in cfg.proj_shapes():
        aux["idx"][n] = ref.topk_rows(params[n], 1)
    # label = parity of count of token 5 — learnable by the head alone
    key = jax.random.PRNGKey(3)
    toks = jax.random.randint(key, (cfg.batch, cfg.seq), 0, 16)
    labels = (toks == 5).sum(-1) % 2
    b = {"tokens": toks.astype(jnp.int32), "labels": labels.astype(jnp.int32),
         "pad_mask": jnp.ones(toks.shape, jnp.float32)}
    js = jax.jit(lambda tr, mm, vv, tt: step(params, tr, mm, vv, aux, b, jnp.float32(1e-2), tt))
    losses = []
    for i in range(60):
        out = js(tr, m, v, jnp.float32(i + 1))
        tr, m, v = out["trainable"], out["m"], out["v"]
        losses.append(float(out["loss"]))
    assert losses[-1] < losses[0] - 0.1
    assert "head" in tr and tr["head"].shape == (cfg.n_classes, cfg.d_model)


def test_eval_fn_shapes():
    for size in ("nano", "enc-micro"):
        cfg = M.SIZES[size]
        fn, ex = M.make_eval_fn(cfg)
        args = ex()
        out = jax.jit(fn)(*args)
        if cfg.n_classes:
            assert out.shape == (cfg.batch, cfg.n_classes)
        else:
            assert out.shape == (cfg.batch, cfg.vocab)


def test_pallas_impl_in_model_matches_jnp():
    """The pallas custom_vjp path composed into the full model must match the
    jnp path (this is what the *_pallas artifact runs)."""
    step_j, ex = M.make_train_step(CFG, "neuroada", k=1, impl="jnp")
    step_p, _ = M.make_train_step(CFG, "neuroada", k=1, impl="pallas")
    params, tr, m, v, aux, batch, _, _ = ex(jax.random.PRNGKey(0))
    for n in CFG.proj_shapes():
        aux["idx"][n] = ref.topk_rows(params[n], 1)
    b = _pattern_batch(CFG)
    oj = step_j(params, tr, m, v, aux, b, jnp.float32(5e-3), jnp.float32(1.0))
    op = step_p(params, tr, m, v, aux, b, jnp.float32(5e-3), jnp.float32(1.0))
    np.testing.assert_allclose(float(oj["loss"]), float(op["loss"]), rtol=1e-5)
    for n in CFG.proj_shapes():
        np.testing.assert_allclose(
            oj["trainable"]["body"][n], op["trainable"]["body"][n], rtol=1e-4, atol=1e-6
        )
