"""AOT artifact integrity: manifest ↔ HLO agreement + the memory claim.

The decisive test here is `test_no_dense_state_in_neuroada_graph`: the
lowered NeuroAda HLO must not allocate any dense d_out×d_in gradient or
optimizer tensor — that absence IS the paper's contribution (Fig. 2 vs §3.3).
"""

import json
import os
import re

import jax.numpy as jnp
import pytest

from compile import aot, model as M

ART = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "..", "artifacts")


def _manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


def test_manifest_covers_plan():
    man = _manifest()
    plan = aot.artifact_plan(man["set"])
    for name, *_ in plan:
        assert name in man["artifacts"], f"missing {name}"
        fpath = os.path.join(ART, man["artifacts"][name]["file"])
        assert os.path.exists(fpath)


def test_hlo_text_wellformed():
    man = _manifest()
    for name, meta in list(man["artifacts"].items())[:6]:
        text = open(os.path.join(ART, meta["file"])).read()
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name


def _entry_params(text):
    """Parse the ENTRY computation's `Arg = ty[dims] parameter(N)` lines,
    returned as {N: (dtype, shape)}."""
    entry = text[text.index("\nENTRY") :]
    params = {}
    for m in re.finditer(
        r"=\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?parameter\((\d+)\)", entry
    ):
        dtype, dims, n = m.group(1), m.group(2), int(m.group(3))
        shape = [int(x) for x in dims.split(",")] if dims else []
        params[n] = (dtype, shape)
    return params


def test_entry_param_count_matches_manifest():
    man = _manifest()
    for name, meta in man["artifacts"].items():
        text = open(os.path.join(ART, meta["file"])).read()
        params = _entry_params(text)
        assert len(params) == len(meta["args"]), (
            f"{name}: hlo={len(params)} manifest={len(meta['args'])}"
        )


def test_arg_shapes_match_hlo():
    man = _manifest()
    for art in ("nano_neuroada_k1", "nano_masked", "nano_eval"):
        meta = man["artifacts"][art]
        text = open(os.path.join(ART, meta["file"])).read()
        params = _entry_params(text)
        for n, a in enumerate(meta["args"]):
            dtype, shape = params[n]
            assert shape == a["shape"], f"{art}/{a['name']}: {shape} vs {a['shape']}"
            assert dtype == a["dtype"], f"{art}/{a['name']}: {dtype} vs {a['dtype']}"


def test_no_dense_state_in_neuroada_graph():
    """No f32[d_out, d_in] tensors flow through grads/opt-state for any
    projection: every occurrence of a dense projection shape must be one of
    the frozen parameter reads (inputs) or their transposes/dots — never an
    add/multiply chain that would indicate dense gradient accumulation.

    We assert a conservative proxy: the *output* signature contains only
    [d_out, k] trainable/m/v tensors, and the HLO contains no dense-shaped
    `add` ops beyond a small bound (the forward residual adds)."""
    man = _manifest()
    meta = man["artifacts"]["nano_neuroada_k1"]
    cfg = M.SIZES["nano"]
    for o in meta["outputs"]:
        if o["name"].split(".")[0] in ("m", "v", "trainable"):
            d_out_k = o["shape"]
            assert d_out_k[1] == meta["k"], o
    text = open(os.path.join(ART, meta["file"])).read()
    # dense projection shapes, e.g. f32[256,64] for w1
    dense_shapes = {f"f32[{o},{i}]" for o, i in cfg.proj_shapes().values()}
    bad = []
    for line in text.splitlines():
        ls = line.strip()
        if any(s + " add(" in ls or s + " multiply(" in ls for s in dense_shapes):
            bad.append(ls)
    assert not bad, f"dense-state-shaped arithmetic in NeuroAda graph:\n" + "\n".join(bad[:5])


def test_masked_graph_does_have_dense_state():
    """Contrast check: the masked baseline MUST carry dense gradients —
    that's the memory cost Figure 5 measures."""
    man = _manifest()
    meta = man["artifacts"]["nano_masked"]
    cfg = M.SIZES["nano"]
    dense = [o for o in meta["outputs"] if o["name"].startswith("m.") and o["shape"] == [256, 64]]
    assert dense, "masked method lost its dense optimizer state?"


def test_trainable_param_percent():
    """Reproduce the paper's params% accounting (Tables 2/3 leftmost col)."""
    man = _manifest()
    for name, meta in man["artifacts"].items():
        if meta.get("entry") != "train" or meta.get("method") != "neuroada":
            continue
        cfg = M.SIZES[meta["size"]]
        rows = sum(o for o, _ in cfg.proj_shapes().values())
        expected = rows * meta["k"]
        enc_head = cfg.n_classes * cfg.d_model if cfg.n_classes else 0
        assert meta["trainable_params"] == expected + enc_head, name
