"""AOT compile path: lower every L2 entry point to HLO text + manifest.

Runs ONCE (`make artifacts`); python never executes at request time.  The
interchange format is HLO **text**, not a serialized HloModuleProto: jax ≥0.5
emits protos with 64-bit instruction ids which the `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Every artifact is recorded in artifacts/manifest.json with its flat argument
/output order (pytree paths), shapes, dtypes, model config and PEFT metadata,
so the rust runtime can marshal buffers without any python at runtime.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

_DTYPE = {"float32": "f32", "int32": "s32", "float64": "f64", "int64": "s64",
          "bfloat16": "bf16", "bool": "pred"}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return ".".join(parts)


def _flat_sig(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [
        {
            "name": _path_str(path),
            "shape": list(leaf.shape),
            "dtype": _DTYPE[str(leaf.dtype)],
        }
        for path, leaf in flat
    ]


def _trainable_count(sig_args) -> int:
    return sum(
        int(jnp.prod(jnp.asarray(a["shape"])) if a["shape"] else 1)
        for a in sig_args
        if a["name"].startswith("trainable.")
    )


def lower_train(cfg: M.ModelConfig, method: str, *, k: int = 1, lora_r: int = 8,
                impl: str = "jnp"):
    step, example_args = M.make_train_step(cfg, method, k=k, lora_r=lora_r, impl=impl)
    params, trainable, m, v, aux, batch, lr, t = example_args()
    args = {"params": params, "trainable": trainable, "m": m, "v": v,
            "aux": aux, "batch": batch, "lr": lr, "t": t}

    def entry(a):
        return step(a["params"], a["trainable"], a["m"], a["v"], a["aux"],
                    a["batch"], a["lr"], a["t"])

    lowered = jax.jit(entry).lower(args)
    out_shape = jax.eval_shape(entry, args)
    return lowered, _flat_sig(args), _flat_sig(out_shape)


def lower_pretrain(cfg: M.ModelConfig):
    step, example_args = M.make_train_step(cfg, "pretrain")
    params, m, v, lr, t = example_args()
    args = {"params": params, "m": m, "v": v,
            "batch": {
                "tokens": jnp.zeros((cfg.batch, cfg.seq), jnp.int32),
                "targets": jnp.zeros((cfg.batch, cfg.seq), jnp.int32),
                "loss_mask": jnp.ones((cfg.batch, cfg.seq), jnp.float32),
                "pad_mask": jnp.ones((cfg.batch, cfg.seq), jnp.float32),
            },
            "lr": lr, "t": t}

    def entry(a):
        return step(a["params"], a["m"], a["v"], a["batch"], a["lr"], a["t"])

    lowered = jax.jit(entry).lower(args)
    out_shape = jax.eval_shape(entry, args)
    return lowered, _flat_sig(args), _flat_sig(out_shape)


def lower_gradprobe(cfg: M.ModelConfig):
    """Warm-up gradient probe (Figure 7 'Gradient' selection): dense
    ∂L/∂W per projection for one LM batch, evaluated at the pretrained
    weights (delta = 0). Output: one [d_out, d_in] tensor per projection."""

    def probe(a):
        params, batch = a["params"], a["batch"]
        zero = {n: jnp.zeros(sh, jnp.float32) for n, sh in cfg.proj_shapes().items()}

        def loss_fn(delta):
            adapt = M.make_adapt("full", delta, {})
            return M.lm_loss(cfg, params, adapt, batch["tokens"], batch["targets"],
                             batch["loss_mask"], batch["pad_mask"])

        return jax.grad(loss_fn)(zero)

    params = M.init_params(cfg, jax.random.PRNGKey(0))
    args = {"params": params,
            "batch": {
                "tokens": jnp.zeros((cfg.batch, cfg.seq), jnp.int32),
                "targets": jnp.zeros((cfg.batch, cfg.seq), jnp.int32),
                "loss_mask": jnp.ones((cfg.batch, cfg.seq), jnp.float32),
                "pad_mask": jnp.ones((cfg.batch, cfg.seq), jnp.float32),
            }}
    lowered = jax.jit(probe).lower(args)
    out_shape = jax.eval_shape(probe, args)
    return lowered, _flat_sig(args), _flat_sig(out_shape)


def lower_eval(cfg: M.ModelConfig):
    fn, example_args = M.make_eval_fn(cfg)
    ex = example_args()
    if cfg.n_classes:  # encoder: no last_pos (would be DCE'd, desyncing the manifest)
        params, biases, tokens, pad_mask = ex
        args = {"params": params, "biases": biases, "tokens": tokens, "pad_mask": pad_mask}

        def entry(a):
            return fn(a["params"], a["biases"], a["tokens"], a["pad_mask"])
    else:
        params, biases, tokens, pad_mask, last_pos = ex
        args = {"params": params, "biases": biases, "tokens": tokens,
                "pad_mask": pad_mask, "last_pos": last_pos}

        def entry(a):
            return fn(a["params"], a["biases"], a["tokens"], a["pad_mask"], a["last_pos"])

    lowered = jax.jit(entry).lower(args)
    out_shape = jax.eval_shape(entry, args)
    return lowered, _flat_sig(args), _flat_sig(out_shape)


def lower_eval_bypass(cfg: M.ModelConfig, k: int):
    """Serving-bypass eval (decoder): extra scatter inputs `delta.idx.*` /
    `delta.theta.*` apply the NeuroAda deltas in-graph, unmerged — the HLO
    path of rust's `serve` registry bypass."""
    fn, example_args = M.make_eval_bypass_fn(cfg, k)
    params, idx, theta, tokens, pad_mask, last_pos = example_args()
    args = {"params": params, "delta": {"idx": idx, "theta": theta},
            "tokens": tokens, "pad_mask": pad_mask, "last_pos": last_pos}

    def entry(a):
        return fn(a["params"], a["delta"]["idx"], a["delta"]["theta"],
                  a["tokens"], a["pad_mask"], a["last_pos"])

    lowered = jax.jit(entry).lower(args)
    out_shape = jax.eval_shape(entry, args)
    return lowered, _flat_sig(args), _flat_sig(out_shape)


# ---------------------------------------------------------------------------
# Artifact set
# ---------------------------------------------------------------------------


def artifact_plan(set_name: str):
    """(name, size, entry, method, k, impl) for every artifact.

    `quick` is the subset the fast test loop uses; `default` is what the
    experiment harness needs; `full` adds the scale-extrapolation config.
    """
    plan = []

    def add(size, method, k=0, impl="jnp"):
        if method in ("eval", "pretrain", "gradprobe", "eval_bypass"):
            name = f"{size}_{method}"
        elif method in ("neuroada",):
            name = f"{size}_{method}_k{k}" + ("_pallas" if impl == "pallas" else "")
        else:
            name = f"{size}_{method}"
        plan.append((name, size, method, k, impl))

    # quick: enough for rust integration tests
    add("nano", "pretrain")
    add("nano", "gradprobe")
    add("nano", "neuroada", k=1)
    add("nano", "neuroada", k=2)
    add("nano", "neuroada", k=4)
    add("nano", "neuroada", k=8)
    add("nano", "neuroada", k=1, impl="pallas")  # pallas-in-graph proof
    add("nano", "masked")
    add("nano", "full")
    add("nano", "lora")
    add("nano", "bitfit")
    add("nano", "eval")
    add("nano", "eval_bypass", k=1)  # serving: unmerged scatter-input eval
    if set_name == "quick":
        return plan
    add("micro", "pretrain")
    add("small", "pretrain")
    add("base", "pretrain")
    add("enc-micro", "pretrain")

    # budget sweeps (Fig 4/6/7) live on micro
    for k in (1, 2, 4, 8, 16):
        add("micro", "neuroada", k=k)
    add("micro", "masked")
    add("micro", "full")
    add("micro", "lora")
    add("micro", "bitfit")
    add("micro", "eval")
    add("micro", "eval_bypass", k=1)  # serving: unmerged scatter-input eval

    # headline tables (T2/T3) on small; fig5 needs masked/full at every size
    add("small", "neuroada", k=1)
    add("small", "neuroada", k=16)
    add("small", "masked")
    add("small", "full")
    add("small", "lora")
    add("small", "bitfit")
    add("small", "eval")

    add("base", "neuroada", k=1)
    add("base", "neuroada", k=16)
    add("base", "masked")
    add("base", "full")
    add("base", "lora")
    add("base", "eval")

    # GLUE-like suite on the encoder
    add("enc-micro", "neuroada", k=1)
    add("enc-micro", "neuroada", k=16)
    add("enc-micro", "masked")
    add("enc-micro", "full")
    add("enc-micro", "lora")
    add("enc-micro", "bitfit")
    add("enc-micro", "eval")

    if set_name == "full":
        add("large", "neuroada", k=1)
        add("large", "eval")
    return plan


def build(out_dir: str, set_name: str, only: str | None = None) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": "hlo-text", "set": set_name, "artifacts": {}}
    man_path = os.path.join(out_dir, "manifest.json")
    if only and os.path.exists(man_path):
        # --only is an incremental re-lower: merge into the existing manifest.
        with open(man_path) as f:
            manifest = json.load(f)
    for name, size, method, k, impl in artifact_plan(set_name):
        if only and only not in name:
            continue
        cfg = M.SIZES[size]
        if method == "eval":
            lowered, sig_in, sig_out = lower_eval(cfg)
            meta = {"entry": "eval"}
        elif method == "eval_bypass":
            lowered, sig_in, sig_out = lower_eval_bypass(cfg, k)
            meta = {"entry": "eval_bypass", "k": k}
        elif method == "pretrain":
            lowered, sig_in, sig_out = lower_pretrain(cfg)
            meta = {"entry": "pretrain"}
        elif method == "gradprobe":
            lowered, sig_in, sig_out = lower_gradprobe(cfg)
            meta = {"entry": "gradprobe"}
        else:
            lowered, sig_in, sig_out = lower_train(cfg, method, k=k, impl=impl)
            meta = {"entry": "train", "method": method, "k": k, "impl": impl,
                    "trainable_params": _trainable_count(sig_in)}
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": fname,
            "size": size,
            "model": {
                "vocab": cfg.vocab, "d_model": cfg.d_model, "n_layers": cfg.n_layers,
                "n_heads": cfg.n_heads, "d_ff": cfg.d_ff, "seq": cfg.seq,
                "batch": cfg.batch, "causal": cfg.causal, "n_classes": cfg.n_classes,
                "backbone_params": cfg.n_backbone_params(),
            },
            "args": sig_in,
            "outputs": sig_out,
            **meta,
        }
        print(f"  wrote {fname} ({len(text) / 1e6:.2f} MB, {len(sig_in)} args)", flush=True)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"manifest: {len(manifest['artifacts'])} artifacts -> {out_dir}/manifest.json")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--set", default="default", choices=["quick", "default", "full"])
    ap.add_argument("--only", default=None, help="substring filter on artifact names")
    a = ap.parse_args()
    build(a.out, a.set, a.only)


if __name__ == "__main__":
    main()
