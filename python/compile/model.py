"""Layer-2: the JAX compute graphs that get AOT-lowered to HLO.

A small LLaMA-style transformer (decoder LM for the reasoning suites and the
in-repo synthetic pretraining; encoder + classifier head for the GLUE-like
suite) with FIVE fine-tuning step variants, each a single fused
fwd + bwd + AdamW HLO graph:

  neuroada — the paper's method: per projection a compact (idx [d_out,k],
             θ [d_out,k]) bypass; grads/optimizer state exist ONLY at the
             selected coordinates (Eq. 4/6).  A slot_mask input supports the
             Fig. 6 neuron-fraction ablation and sub-k budgets without
             re-lowering.
  masked   — the Figure-2 baseline: dense per-projection delta with a binary
             mask multiplied into the gradient.  Full-size gradients and
             AdamW moments, by design (that is the memory cost the paper
             measures against).
  lora     — low-rank A/B per projection (B zero-init), scale α/r.
  bitfit   — trainable bias per projection.
  full     — dense delta per projection, no mask (full fine-tuning of the
             linear sublayers; also the in-repo pretraining step).

The backbone weights are always *inputs* to the graph and are never updated;
L3 (rust) owns them as device-resident buffers.  The LR schedule lives in L3
too — each step takes the scalar lr for that step, so one artifact serves any
schedule in Tables 5–7.

Python never runs at request time: `aot.py` lowers everything here once to
artifacts/*.hlo.txt.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .kernels.neuroada import neuroada_linear

# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    seq: int
    batch: int
    causal: bool = True
    n_classes: int = 0  # >0 → encoder classifier head
    dtype: Any = jnp.float32

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def proj_shapes(self) -> Dict[str, Tuple[int, int]]:
        """Every PEFT-adapted linear weight, name → (d_out, d_in).

        Rows are neurons (paper §3.1); these six projections per block are
        exactly the set NeuroAda adapts (embeddings / norms stay frozen).
        """
        d, f = self.d_model, self.d_ff
        shapes: Dict[str, Tuple[int, int]] = {}
        for l in range(self.n_layers):
            shapes[f"l{l}.wq"] = (d, d)
            shapes[f"l{l}.wk"] = (d, d)
            shapes[f"l{l}.wv"] = (d, d)
            shapes[f"l{l}.wo"] = (d, d)
            shapes[f"l{l}.w1"] = (f, d)
            shapes[f"l{l}.w2"] = (d, f)
        return shapes

    def n_backbone_params(self) -> int:
        n = self.vocab * self.d_model  # tied embedding
        n += sum(o * i for o, i in self.proj_shapes().values())
        n += (2 * self.n_layers + 1) * self.d_model  # rmsnorm scales
        if self.n_classes:
            n += self.n_classes * self.d_model
        return n


SIZES: Dict[str, ModelConfig] = {
    "nano": ModelConfig("nano", vocab=256, d_model=64, n_layers=2, n_heads=4, d_ff=256, seq=32, batch=16),
    "micro": ModelConfig("micro", vocab=512, d_model=128, n_layers=4, n_heads=4, d_ff=512, seq=48, batch=8),
    "small": ModelConfig("small", vocab=1024, d_model=256, n_layers=6, n_heads=8, d_ff=1024, seq=64, batch=8),
    "base": ModelConfig("base", vocab=2048, d_model=512, n_layers=8, n_heads=8, d_ff=2048, seq=64, batch=4),
    # `large` exists as a config preset for scale extrapolation (DESIGN.md §3);
    # lowering it is supported but not part of the default artifact set.
    "large": ModelConfig("large", vocab=4096, d_model=768, n_layers=12, n_heads=12, d_ff=3072, seq=64, batch=2),
    # Encoder (RoBERTa-analog) for the GLUE-like suite: bidirectional + head.
    "enc-micro": ModelConfig(
        "enc-micro", vocab=512, d_model=128, n_layers=4, n_heads=4, d_ff=512, seq=48, batch=16,
        causal=False, n_classes=5,
    ),
}


# ---------------------------------------------------------------------------
# Backbone
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key) -> Dict[str, jnp.ndarray]:
    """Random init — used by python tests and by L3 (re-implemented in rust
    with the same shapes; values don't need to match, pretraining does the
    work)."""
    params: Dict[str, jnp.ndarray] = {}
    keys = jax.random.split(key, 2 + 6 * cfg.n_layers)
    it = iter(keys)
    params["embed"] = jax.random.normal(next(it), (cfg.vocab, cfg.d_model), cfg.dtype) * 0.02
    for name, (o, i) in cfg.proj_shapes().items():
        params[name] = jax.random.normal(next(it), (o, i), cfg.dtype) * (1.0 / math.sqrt(i))
    for l in range(cfg.n_layers):
        params[f"l{l}.ln1"] = jnp.ones((cfg.d_model,), cfg.dtype)
        params[f"l{l}.ln2"] = jnp.ones((cfg.d_model,), cfg.dtype)
    params["ln_f"] = jnp.ones((cfg.d_model,), cfg.dtype)
    if cfg.n_classes:
        params["head"] = jnp.zeros((cfg.n_classes, cfg.d_model), cfg.dtype)
    return params


def _rmsnorm(x, scale):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6) * scale


def _positional(seq: int, d: int, dtype):
    pos = jnp.arange(seq)[:, None].astype(jnp.float32)
    dim = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    ang = pos / jnp.power(10000.0, 2.0 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def _attention(q, k, v, cfg: ModelConfig, pad_mask):
    b, t, d = q.shape
    h, hd = cfg.n_heads, cfg.head_dim
    q = q.reshape(b, t, h, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, t, h, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, t, h, hd).transpose(0, 2, 1, 3)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / math.sqrt(hd)
    neg = jnp.asarray(-1e9, scores.dtype)
    if cfg.causal:
        causal = jnp.tril(jnp.ones((t, t), bool))
        scores = jnp.where(causal[None, None], scores, neg)
    scores = jnp.where(pad_mask[:, None, None, :] > 0, scores, neg)
    att = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    return out.transpose(0, 2, 1, 3).reshape(b, t, d)


def forward(cfg: ModelConfig, params, adapt, tokens, pad_mask):
    """Backbone forward.  `adapt(name, x, w)` wraps every PEFT'd projection —
    each method plugs in its own adapted linear there."""
    x = params["embed"][tokens] + _positional(cfg.seq, cfg.d_model, cfg.dtype)[None]
    for l in range(cfg.n_layers):
        h = _rmsnorm(x, params[f"l{l}.ln1"])
        q = adapt(f"l{l}.wq", h, params[f"l{l}.wq"])
        k = adapt(f"l{l}.wk", h, params[f"l{l}.wk"])
        v = adapt(f"l{l}.wv", h, params[f"l{l}.wv"])
        a = _attention(q, k, v, cfg, pad_mask)
        x = x + adapt(f"l{l}.wo", a, params[f"l{l}.wo"])
        h = _rmsnorm(x, params[f"l{l}.ln2"])
        m = adapt(f"l{l}.w1", h, params[f"l{l}.w1"])
        m = jax.nn.silu(m)
        x = x + adapt(f"l{l}.w2", m, params[f"l{l}.w2"])
    return _rmsnorm(x, params["ln_f"])


def lm_logits(cfg: ModelConfig, params, adapt, tokens, pad_mask):
    h = forward(cfg, params, adapt, tokens, pad_mask)
    return h @ params["embed"].T  # tied head


def cls_logits(cfg: ModelConfig, params, adapt, head_delta, tokens, pad_mask):
    h = forward(cfg, params, adapt, tokens, pad_mask)
    denom = jnp.maximum(pad_mask.sum(-1, keepdims=True), 1.0)
    pooled = (h * pad_mask[..., None]).sum(1) / denom
    return pooled @ (params["head"] + head_delta).T


# ---------------------------------------------------------------------------
# PEFT method adapters
# ---------------------------------------------------------------------------
#
# Each method defines:
#   trainable_spec(cfg, k) -> {name: (shape, dtype)} — what L3 must allocate
#   adapt fn given the trainable pytree
#   grad_transform(grads, aux) — e.g. the masked method multiplies the mask in


def neuroada_spec(cfg: ModelConfig, k: int):
    t = {}
    for name, (o, _i) in cfg.proj_shapes().items():
        t[name] = ((o, k), jnp.float32)
    return t


def dense_spec(cfg: ModelConfig):
    return {name: (shape, jnp.float32) for name, shape in cfg.proj_shapes().items()}


def lora_spec(cfg: ModelConfig, r: int):
    t = {}
    for name, (o, i) in cfg.proj_shapes().items():
        t[name + ".A"] = ((r, i), jnp.float32)
        t[name + ".B"] = ((o, r), jnp.float32)
    return t


def bitfit_spec(cfg: ModelConfig):
    return {name: ((shape[0],), jnp.float32) for name, shape in cfg.proj_shapes().items()}


def make_adapt(method: str, trainable, aux, impl: str = "jnp", lora_alpha: float = 16.0):
    """Build the `adapt(name, x, w)` closure for a method.

    aux: method-specific frozen inputs — neuroada: {"idx": {...}},
    masked: {"mask": {...}} (dense 0/1), others: {}.
    """
    if method == "neuroada":
        idx = aux["idx"]

        def adapt(name, x, w):
            return neuroada_linear(x, w, idx[name], trainable[name], impl=impl)

    elif method in ("masked", "full"):

        def adapt(name, x, w):
            return x @ (jax.lax.stop_gradient(w) + trainable[name]).T

    elif method == "lora":
        r = next(iter(trainable.values())).shape[0]
        scale = lora_alpha / r

        def adapt(name, x, w):
            y = x @ jax.lax.stop_gradient(w).T
            a, bmat = trainable[name + ".A"], trainable[name + ".B"]
            return y + (x @ a.T) @ bmat.T * scale

    elif method == "bitfit":

        def adapt(name, x, w):
            return x @ jax.lax.stop_gradient(w).T + trainable[name]

    elif method == "frozen":

        def adapt(name, x, w):
            return x @ w.T

    else:
        raise ValueError(f"unknown method {method!r}")
    return adapt


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------


def lm_loss(cfg, params, adapt, tokens, targets, loss_mask, pad_mask):
    logits = lm_logits(cfg, params, adapt, tokens, pad_mask)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(loss_mask.sum(), 1.0)
    return (nll * loss_mask).sum() / denom


def cls_loss(cfg, params, adapt, head_delta, tokens, labels, pad_mask):
    logits = cls_logits(cfg, params, adapt, head_delta, tokens, pad_mask)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return nll.mean()


# ---------------------------------------------------------------------------
# AdamW (in-graph). weight_decay = 0 throughout, per Tables 5–7.
# ---------------------------------------------------------------------------

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8


def adamw_update(params, grads, m, v, lr, t):
    """One AdamW step over an arbitrary pytree.  For NeuroAda the tree leaves
    are the compact [d_out, k] θ tensors, so the two moment tensors shrink by
    d_in/k exactly as Eq. (6) claims — the lowered HLO provably allocates no
    dense-shaped state (asserted in tests)."""

    def upd(p, g, mm, vv):
        mm2 = ADAM_B1 * mm + (1 - ADAM_B1) * g
        vv2 = ADAM_B2 * vv + (1 - ADAM_B2) * g * g
        mhat = mm2 / (1 - ADAM_B1**t)
        vhat = vv2 / (1 - ADAM_B2**t)
        return p - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS), mm2, vv2

    flat_p, tree = jax.tree_util.tree_flatten(params)
    flat_g = tree.flatten_up_to(grads)
    flat_m = tree.flatten_up_to(m)
    flat_v = tree.flatten_up_to(v)
    out = [upd(p, g, mm, vv) for p, g, mm, vv in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tree.unflatten([o[0] for o in out])
    new_m = tree.unflatten([o[1] for o in out])
    new_v = tree.unflatten([o[2] for o in out])
    return new_p, new_m, new_v


# ---------------------------------------------------------------------------
# Train / eval steps (the AOT entry points)
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, method: str, *, k: int = 1, lora_r: int = 8,
                    impl: str = "jnp"):
    """Returns (step_fn, example_args_builder).

    Decoder signature:
      step(params, trainable, m, v, aux, tokens, targets, loss_mask, pad_mask,
           lr, t) -> {"trainable", "m", "v", "loss"}
    Encoder adds head_delta (+ its moments) and labels replace targets.
    """

    is_enc = cfg.n_classes > 0

    if method == "pretrain":
        # True full-parameter pretraining (embeddings, norms, projections):
        # builds the converged backbone that all PEFT methods then adapt.
        def pstep(params, m, v, batch, lr, t):
            lr = lr.astype(jnp.float32)
            t = t.astype(jnp.float32)

            def loss_fn(p):
                adapt = make_adapt("frozen", None, {})
                return lm_loss(cfg, p, adapt, batch["tokens"], batch["targets"],
                               batch["loss_mask"], batch["pad_mask"])

            loss, grads = jax.value_and_grad(loss_fn)(params)
            new_p, new_m, new_v = adamw_update(params, grads, m, v, lr, t)
            return {"params": new_p, "m": new_m, "v": new_v, "loss": loss}

        def pexample(key=None):
            params = init_params(cfg, key if key is not None else jax.random.PRNGKey(0))
            zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
            b, s = cfg.batch, cfg.seq
            batch = {
                "tokens": jnp.zeros((b, s), jnp.int32),
                "targets": jnp.zeros((b, s), jnp.int32),
                "loss_mask": jnp.ones((b, s), jnp.float32),
                "pad_mask": jnp.ones((b, s), jnp.float32),
            }
            return (params, zeros, zeros, jnp.asarray(1e-3, jnp.float32),
                    jnp.asarray(1.0, jnp.float32))

        return pstep, pexample

    def step(params, trainable, m, v, aux, batch, lr, t):
        lr = lr.astype(jnp.float32)
        t = t.astype(jnp.float32)

        if is_enc:
            tokens, labels, pad_mask = batch["tokens"], batch["labels"], batch["pad_mask"]

            def loss_fn(tr):
                adapt = make_adapt(method, tr["body"], aux, impl=impl)
                return cls_loss(cfg, params, adapt, tr["head"], tokens, labels, pad_mask)

        else:
            tokens, targets = batch["tokens"], batch["targets"]
            loss_mask, pad_mask = batch["loss_mask"], batch["pad_mask"]

            def loss_fn(tr):
                adapt = make_adapt(method, tr["body"], aux, impl=impl)
                return lm_loss(cfg, params, adapt, tokens, targets, loss_mask, pad_mask)

        loss, grads = jax.value_and_grad(loss_fn)(trainable)

        if method == "neuroada":
            # slot_mask: 1 → slot participates, 0 → frozen (Fig. 6 row
            # fractions / sub-k budgets without re-lowering the graph).
            grads = {
                "body": {n: g * aux["slot_mask"][n] for n, g in grads["body"].items()},
                **({"head": grads["head"]} if is_enc else {}),
            }
        elif method == "masked":
            grads = {
                "body": {n: g * aux["mask"][n] for n, g in grads["body"].items()},
                **({"head": grads["head"]} if is_enc else {}),
            }

        new_tr, new_m, new_v = adamw_update(trainable, grads, m, v, lr, t)
        return {"trainable": new_tr, "m": new_m, "v": new_v, "loss": loss}

    def example_args(key=None):
        key = key if key is not None else jax.random.PRNGKey(0)
        params = init_params(cfg, key)
        if method == "neuroada":
            spec = neuroada_spec(cfg, k)
        elif method in ("masked", "full"):
            spec = dense_spec(cfg)
        elif method == "lora":
            spec = lora_spec(cfg, lora_r)
        elif method == "bitfit":
            spec = bitfit_spec(cfg)
        else:
            raise ValueError(method)
        body = {n: jnp.zeros(s, d) for n, (s, d) in spec.items()}
        trainable = {"body": body}
        if is_enc:
            trainable["head"] = jnp.zeros((cfg.n_classes, cfg.d_model), jnp.float32)
        zeros = jax.tree_util.tree_map(jnp.zeros_like, trainable)
        aux: Dict[str, Any] = {}
        if method == "neuroada":
            aux["idx"] = {
                n: jnp.zeros((sh[0], k), jnp.int32) for n, sh in cfg.proj_shapes().items()
            }
            aux["slot_mask"] = {n: jnp.ones((sh[0], k), jnp.float32) for n, sh in cfg.proj_shapes().items()}
        elif method == "masked":
            aux["mask"] = {n: jnp.ones(sh, jnp.float32) for n, sh in cfg.proj_shapes().items()}
        b, s = cfg.batch, cfg.seq
        if is_enc:
            batch = {
                "tokens": jnp.zeros((b, s), jnp.int32),
                "labels": jnp.zeros((b,), jnp.int32),
                "pad_mask": jnp.ones((b, s), jnp.float32),
            }
        else:
            batch = {
                "tokens": jnp.zeros((b, s), jnp.int32),
                "targets": jnp.zeros((b, s), jnp.int32),
                "loss_mask": jnp.ones((b, s), jnp.float32),
                "pad_mask": jnp.ones((b, s), jnp.float32),
            }
        lr = jnp.asarray(1e-3, jnp.float32)
        t = jnp.asarray(1.0, jnp.float32)
        return (params, trainable, zeros, zeros, aux, batch, lr, t)

    return step, example_args


def make_eval_fn(cfg: ModelConfig):
    """Eval entry: decoder → last-position LM logits [B, V] (multiple-choice
    scoring + greedy decode); encoder → class logits.

    Takes per-projection `biases` so ALL methods evaluate through one
    artifact: NeuroAda/masked/full/LoRA merge their deltas into the weights
    (Algorithm 1 Phase 3) and pass zero biases; BitFit — whose biases cannot
    merge into a bias-free backbone — passes them here."""

    is_enc = cfg.n_classes > 0

    def biased_adapt(biases):
        def adapt(name, x, w):
            return x @ w.T + biases[name]

        return adapt

    if is_enc:
        # No last_pos arg: XLA drops unused entry parameters during
        # stablehlo→XlaComputation conversion, which would desync the
        # manifest signature from the HLO (caught by test_aot.py).
        def eval_fn(params, biases, tokens, pad_mask):
            adapt = biased_adapt(biases)
            return cls_logits(cfg, params, adapt, jnp.zeros_like(params["head"]), tokens, pad_mask)

    else:

        def eval_fn(params, biases, tokens, pad_mask, last_pos=None):
            adapt = biased_adapt(biases)
            logits = lm_logits(cfg, params, adapt, tokens, pad_mask)
            return jnp.take_along_axis(logits, last_pos[:, None, None], axis=1)[:, 0]

    def example_args(key=None):
        params = init_params(cfg, key if key is not None else jax.random.PRNGKey(0))
        biases = {n: jnp.zeros((sh[0],), jnp.float32) for n, sh in cfg.proj_shapes().items()}
        base = (
            params,
            biases,
            jnp.zeros((cfg.batch, cfg.seq), jnp.int32),
            jnp.ones((cfg.batch, cfg.seq), jnp.float32),
        )
        if is_enc:
            return base
        return base + (jnp.zeros((cfg.batch,), jnp.int32),)

    return eval_fn, example_args


def make_eval_bypass_fn(cfg: ModelConfig, k: int):
    """Serving-bypass eval entry (decoder only): last-position LM logits with
    the NeuroAda deltas applied *in-graph* through an extra scatter input —
    per projection an (idx [d_out, k], θ [d_out, k]) pair — instead of being
    pre-merged into the weights.

    This is the HLO twin of rust's `serve` unmerged path: one resident
    backbone plus per-request compact deltas serves any number of adapters;
    all-zero θ reproduces the frozen backbone exactly, so unregistered
    projections cost nothing but the gather."""
    if cfg.n_classes:
        raise ValueError("eval_bypass is decoder-only")

    def eval_fn(params, idx, theta, tokens, pad_mask, last_pos):
        def adapt(name, x, w):
            return neuroada_linear(x, w, idx[name], theta[name], impl="jnp")

        logits = lm_logits(cfg, params, adapt, tokens, pad_mask)
        return jnp.take_along_axis(logits, last_pos[:, None, None], axis=1)[:, 0]

    def example_args(key=None):
        params = init_params(cfg, key if key is not None else jax.random.PRNGKey(0))
        idx = {n: jnp.zeros((sh[0], k), jnp.int32) for n, sh in cfg.proj_shapes().items()}
        theta = {n: jnp.zeros((sh[0], k), jnp.float32) for n, sh in cfg.proj_shapes().items()}
        return (
            params,
            idx,
            theta,
            jnp.zeros((cfg.batch, cfg.seq), jnp.int32),
            jnp.ones((cfg.batch, cfg.seq), jnp.float32),
            jnp.zeros((cfg.batch,), jnp.int32),
        )

    return eval_fn, example_args


__all__ = [
    "ModelConfig", "SIZES", "init_params", "forward", "lm_logits", "cls_logits",
    "make_adapt", "lm_loss", "cls_loss", "adamw_update", "make_train_step",
    "make_eval_fn", "make_eval_bypass_fn", "neuroada_spec", "dense_spec",
    "lora_spec", "bitfit_spec",
]
