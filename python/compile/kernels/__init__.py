# L1: Pallas kernels for the NeuroAda sparse-delta hot path + oracles.
from . import neuroada, ref, topk  # noqa: F401
