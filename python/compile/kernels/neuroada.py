"""Layer-1 Pallas kernels for NeuroAda's sparse-delta linear layer.

The paper's compute hot-spot is the featherlight forward/backward of a linear
layer whose weight is `W + Δ`, where Δ is zero everywhere except k trainable
coordinates per row (Eq. 4):

    y[b, i]      = Σ_t W[i, t]·x[b, t]  +  Σ_j Θ[i, j]·x[b, I[i, j]]
    dΘ[i, j]     = Σ_b ĝ[b, i]·x[b, I[i, j]]
    dx[b, t]     = Σ_i ĝ[b, i]·W[i, t]  +  Σ_{(i,j): I[i,j]=t} ĝ[b, i]·Θ[i, j]

Hardware adaptation (paper = CUDA fused scatter-add; here = TPU-style Pallas):
rather than scattering Δ into a dense mask, each grid step co-tiles a block of
rows of (W, I, Θ) into VMEM, gathers the k needed x columns per row, and runs
a tiny `[B_blk, R_blk, k]` contraction next to the dense `x @ W_blkᵀ` MXU
tile.  Θ, I and both AdamW moments for a whole projection fit in VMEM for
k ≤ 32 (see DESIGN.md §2), so the sparse path adds no HBM traffic of its own.

All kernels run with ``interpret=True``: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute; the interpret path lowers to
plain HLO so the AOT artifacts run anywhere.  Correctness is pinned to
``ref.py`` via pytest/hypothesis.

Two implementations are exposed and tested against each other:

* ``impl="jnp"``    — gather/scatter composition; JAX autodiff derives the
                      backward (scatter-add), no dense d_out×d_in temporary.
* ``impl="pallas"`` — custom_vjp routing forward AND backward through the
                      Pallas kernels below.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block sizes. On a real TPU these would be tuned to VMEM (see DESIGN.md §7);
# under interpret=True they only shape the HLO loop structure, so we keep them
# modest to bound per-step working sets.
DEFAULT_BLOCK_B = 64
DEFAULT_BLOCK_R = 128


def _ceil_to(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def _pick_block(n: int, preferred: int) -> int:
    """Largest divisor-friendly block ≤ preferred (pads otherwise)."""
    return min(preferred, max(n, 1))


# ---------------------------------------------------------------------------
# Forward kernel: y = x Wᵀ + gather-Δ contraction
# ---------------------------------------------------------------------------


def _fwd_kernel(x_ref, w_ref, idx_ref, th_ref, o_ref):
    x = x_ref[...]  # [B_blk, d_in]
    w = w_ref[...]  # [R_blk, d_in]
    idx = idx_ref[...]  # [R_blk, k]
    th = th_ref[...]  # [R_blk, k]
    dense = jnp.dot(x, w.T, preferred_element_type=jnp.float32)
    # Gather the k needed columns of x per output row: [B_blk, R_blk, k].
    xg = x[:, idx]
    delta = jnp.einsum("brk,rk->br", xg, th.astype(jnp.float32))
    o_ref[...] = (dense + delta).astype(o_ref.dtype)


def sparse_delta_matmul_pallas(
    x, w, idx, theta, *, block_b: int = DEFAULT_BLOCK_B, block_r: int = DEFAULT_BLOCK_R
):
    """Pallas forward. Shapes: x [B, d_in], w [d_out, d_in], idx/theta [d_out, k]."""
    b, d_in = x.shape
    d_out, _ = w.shape
    k = idx.shape[1]
    bb = _pick_block(b, block_b)
    br = _pick_block(d_out, block_r)
    bp, rp = _ceil_to(b, bb), _ceil_to(d_out, br)
    xp = jnp.pad(x, ((0, bp - b), (0, 0))) if bp != b else x
    wp = jnp.pad(w, ((0, rp - d_out), (0, 0))) if rp != d_out else w
    ip = jnp.pad(idx, ((0, rp - d_out), (0, 0))) if rp != d_out else idx
    tp = jnp.pad(theta, ((0, rp - d_out), (0, 0))) if rp != d_out else theta

    out = pl.pallas_call(
        _fwd_kernel,
        out_shape=jax.ShapeDtypeStruct((bp, rp), x.dtype),
        grid=(bp // bb, rp // br),
        in_specs=[
            pl.BlockSpec((bb, d_in), lambda i, j: (i, 0)),
            pl.BlockSpec((br, d_in), lambda i, j: (j, 0)),
            pl.BlockSpec((br, k), lambda i, j: (j, 0)),
            pl.BlockSpec((br, k), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bb, br), lambda i, j: (i, j)),
        interpret=True,
    )(xp, wp, ip, tp)
    return out[:b, :d_out]


# ---------------------------------------------------------------------------
# Backward kernels
# ---------------------------------------------------------------------------


def _dtheta_kernel(g_ref, x_ref, idx_ref, o_ref):
    g = g_ref[...]  # [B, R_blk]
    x = x_ref[...]  # [B, d_in]
    idx = idx_ref[...]  # [R_blk, k]
    xg = x[:, idx]  # [B, R_blk, k]
    o_ref[...] = jnp.einsum("br,brk->rk", g.astype(jnp.float32), xg.astype(jnp.float32)).astype(
        o_ref.dtype
    )


def sparse_delta_dtheta_pallas(x, idx, g, *, block_r: int = DEFAULT_BLOCK_R):
    """dΘ[i,j] = Σ_b g[b,i]·x[b, I[i,j]].  g: [B, d_out] → [d_out, k]."""
    b, d_in = x.shape
    d_out = g.shape[1]
    k = idx.shape[1]
    br = _pick_block(d_out, block_r)
    rp = _ceil_to(d_out, br)
    gp = jnp.pad(g, ((0, 0), (0, rp - d_out))) if rp != d_out else g
    ip = jnp.pad(idx, ((0, rp - d_out), (0, 0))) if rp != d_out else idx

    out = pl.pallas_call(
        _dtheta_kernel,
        out_shape=jax.ShapeDtypeStruct((rp, k), x.dtype),
        grid=(rp // br,),
        in_specs=[
            pl.BlockSpec((b, br), lambda j: (0, j)),
            pl.BlockSpec((b, d_in), lambda j: (0, 0)),
            pl.BlockSpec((br, k), lambda j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((br, k), lambda j: (j, 0)),
        interpret=True,
    )(gp, x, ip)
    return out[:d_out]


def _dx_kernel(g_ref, w_ref, idx_ref, th_ref, o_ref):
    """Accumulates over the row-block grid axis (output revisited per j)."""
    j = pl.program_id(1)
    g = g_ref[...]  # [B_blk, R_blk]
    w = w_ref[...]  # [R_blk, d_in]
    idx = idx_ref[...]  # [R_blk, k]
    th = th_ref[...]  # [R_blk, k]

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    dense = jnp.dot(g, w, preferred_element_type=jnp.float32)
    # Sparse part: dx[b, I[i, j]] += g[b, i]·Θ[i, j], scattered per row block.
    vals = g[:, :, None].astype(jnp.float32) * th[None, :, :].astype(jnp.float32)
    acc = o_ref[...].astype(jnp.float32) + dense
    acc = acc.at[:, idx].add(vals)
    o_ref[...] = acc.astype(o_ref.dtype)


def sparse_delta_dx_pallas(
    g, w, idx, theta, *, block_b: int = DEFAULT_BLOCK_B, block_r: int = DEFAULT_BLOCK_R
):
    """dx = g (W + Δ).  g: [B, d_out] → [B, d_in]."""
    b, d_out = g.shape
    _, d_in = w.shape
    k = idx.shape[1]
    bb = _pick_block(b, block_b)
    br = _pick_block(d_out, block_r)
    bp, rp = _ceil_to(b, bb), _ceil_to(d_out, br)
    gp = jnp.pad(g, ((0, bp - b), (0, rp - d_out)))
    wp = jnp.pad(w, ((0, rp - d_out), (0, 0))) if rp != d_out else w
    ip = jnp.pad(idx, ((0, rp - d_out), (0, 0))) if rp != d_out else idx
    # Padded rows carry Θ=0 so their scatter contributions vanish.
    tp = jnp.pad(theta, ((0, rp - d_out), (0, 0))) if rp != d_out else theta

    out = pl.pallas_call(
        _dx_kernel,
        out_shape=jax.ShapeDtypeStruct((bp, d_in), g.dtype),
        grid=(bp // bb, rp // br),
        in_specs=[
            pl.BlockSpec((bb, br), lambda i, j: (i, j)),
            pl.BlockSpec((br, d_in), lambda i, j: (j, 0)),
            pl.BlockSpec((br, k), lambda i, j: (j, 0)),
            pl.BlockSpec((br, k), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bb, d_in), lambda i, j: (i, 0)),
        interpret=True,
    )(gp, wp, ip, tp)
    return out[:b]


# ---------------------------------------------------------------------------
# jnp composition (autodiff-friendly; no dense d_out×d_in temporary)
# ---------------------------------------------------------------------------


def sparse_delta_matmul_jnp(x, w, idx, theta):
    """Gather/einsum composition of Eq. 4.  Autodiff of the gather is a
    scatter-add, so JAX derives exactly the sparse backward — the full
    gradient matrix of Figure 2's mask-based approach never exists."""
    dense = x @ jax.lax.stop_gradient(w).T
    xg = x[:, idx]  # [B, d_out, k]
    return dense + jnp.einsum("brk,rk->br", xg, theta)


# ---------------------------------------------------------------------------
# custom_vjp wrapper selecting the implementation
# ---------------------------------------------------------------------------


@jax.custom_vjp
def _neuroada_linear_pallas(x, w, idx, theta):
    return sparse_delta_matmul_pallas(x, w, idx, theta)


def _fwd_rule(x, w, idx, theta):
    y = sparse_delta_matmul_pallas(x, w, idx, theta)
    return y, (x, w, idx, theta)


def _bwd_rule(res, g):
    x, w, idx, theta = res
    dx = sparse_delta_dx_pallas(g, w, idx, theta)
    dth = sparse_delta_dtheta_pallas(x, idx, g)
    # w is frozen and idx is metadata: their cotangents are dead outputs
    # (jax.grad never requests them) and are DCE'd out of the lowered HLO —
    # asserted by tests/test_aot.py::test_no_dense_grad_temporaries.
    return dx, jnp.zeros_like(w), None, dth


# idx is int — jax treats integer cotangents as symbolic zero (None allowed).
_neuroada_linear_pallas.defvjp(_fwd_rule, _bwd_rule)


def neuroada_linear(x, w, idx, theta, *, impl: str = "jnp"):
    """The NeuroAda linear layer: y = x·(W+Δ)ᵀ with Δ given compactly.

    Args:
      x:     [..., d_in] activations (leading dims flattened internally).
      w:     [d_out, d_in] frozen pretrained weight.
      idx:   [d_out, k] int32 selected input connections per neuron.
      theta: [d_out, k] trainable bypass values (zero-init).
      impl:  "jnp" (autodiff composition) or "pallas" (custom_vjp kernels).
    """
    lead = x.shape[:-1]
    d_in = x.shape[-1]
    x2 = x.reshape((-1, d_in))
    if impl == "pallas":
        y = _neuroada_linear_pallas(x2, w, idx, theta)
    elif impl == "jnp":
        y = sparse_delta_matmul_jnp(x2, w, idx, theta)
    else:
        raise ValueError(f"unknown impl {impl!r}")
    return y.reshape(lead + (w.shape[0],))


__all__ = [
    "neuroada_linear",
    "sparse_delta_matmul_pallas",
    "sparse_delta_matmul_jnp",
    "sparse_delta_dtheta_pallas",
    "sparse_delta_dx_pallas",
    "DEFAULT_BLOCK_B",
    "DEFAULT_BLOCK_R",
]
