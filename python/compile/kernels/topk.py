"""Layer-1 Pallas kernel: neuron-wise top-k |w| selection (Eq. 2).

Phase 1 of Algorithm 1 — run ONCE, offline, before fine-tuning.  For each
neuron (row of W) the k largest-magnitude input connections are identified;
those coordinates receive the zero-initialized bypass parameters Θ.

Spec (shared with ref.topk_rows and the rust `peft::selection` module):
indices come out ordered by descending |w|, ties broken by the LOWER index.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_R = 256


def _ceil_to(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def _topk_kernel(w_ref, idx_ref, val_ref):
    w = w_ref[...]
    vals, idx = jax.lax.top_k(jnp.abs(w), idx_ref.shape[-1])
    idx_ref[...] = idx.astype(jnp.int32)
    val_ref[...] = vals.astype(val_ref.dtype)


def topk_rows_pallas(w, k: int, *, block_r: int = DEFAULT_BLOCK_R):
    """Per-row top-k of |w|.

    Returns (idx [d_out, k] int32, vals [d_out, k] — the |w| magnitudes, which
    the coordinator logs for selection diagnostics).
    """
    d_out, d_in = w.shape
    br = min(block_r, d_out)
    rp = _ceil_to(d_out, br)
    wp = jnp.pad(w, ((0, rp - d_out), (0, 0))) if rp != d_out else w

    idx, vals = pl.pallas_call(
        _topk_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((rp, k), jnp.int32),
            jax.ShapeDtypeStruct((rp, k), w.dtype),
        ),
        grid=(rp // br,),
        in_specs=[pl.BlockSpec((br, d_in), lambda j: (j, 0))],
        out_specs=(
            pl.BlockSpec((br, k), lambda j: (j, 0)),
            pl.BlockSpec((br, k), lambda j: (j, 0)),
        ),
        interpret=True,
    )(wp)
    return idx[:d_out], vals[:d_out]


def select(w, k: int, strategy: str = "magnitude", *, key=None, grads=None):
    """Selection strategies compared in Figure 7.

    magnitude — top-k |w| (the NeuroAda default: task-agnostic, no warm-up)
    gradient  — top-k |∂L/∂w| from a warm-up gradient (requires `grads`)
    reverse   — bottom-k |w|
    random    — uniform k distinct coordinates per row (requires `key`)
    """
    if strategy == "magnitude":
        idx, _ = topk_rows_pallas(w, k)
        return idx
    if strategy == "gradient":
        if grads is None:
            raise ValueError("gradient strategy needs a warm-up gradient")
        idx, _ = topk_rows_pallas(grads, k)
        return idx
    if strategy == "reverse":
        # bottom-k |w|: top-k of the negated magnitudes (cannot reuse the
        # kernel directly — it takes |·| internally, which would cancel).
        _, idx = jax.lax.top_k(-jnp.abs(w), k)
        return idx.astype(jnp.int32)
    if strategy == "random":
        if key is None:
            raise ValueError("random strategy needs a PRNG key")
        d_out, d_in = w.shape
        # Distinct per row: rank k random uniforms over d_in.
        u = jax.random.uniform(key, (d_out, d_in))
        _, idx = jax.lax.top_k(u, k)
        return idx.astype(jnp.int32)
    raise ValueError(f"unknown strategy {strategy!r}")


__all__ = ["topk_rows_pallas", "select", "DEFAULT_BLOCK_R"]
