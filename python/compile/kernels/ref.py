"""Pure-jnp oracles for the NeuroAda kernels.

These are the CORE correctness signal: every Pallas kernel in this package is
pytest-compared against the function of the same name here (see
python/tests/).  They are deliberately written in the most naive/dense way
possible — materialize the full delta matrix, full gradients — so that any
sparsity bookkeeping bug in the kernels shows up as a numeric mismatch.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def scatter_delta_dense(w_shape, idx, theta):
    """Materialize the dense delta matrix Δ ∈ R^{d_out×d_in}.

    Δ[i, idx[i, j]] += theta[i, j]   (duplicate indices accumulate, matching
    the kernel's sum-over-j semantics).
    """
    d_out, d_in = w_shape
    rows = jnp.arange(d_out)[:, None]  # broadcast against [d_out, k]
    return jnp.zeros((d_out, d_in), dtype=theta.dtype).at[rows, idx].add(theta)


def sparse_delta_matmul(x, w, idx, theta):
    """Oracle for the NeuroAda forward: y = x Wᵀ + x Δᵀ.

    x: [B, d_in], w: [d_out, d_in], idx: [d_out, k] int32, theta: [d_out, k].
    Returns y: [B, d_out].
    """
    delta = scatter_delta_dense(w.shape, idx, theta)
    return x @ w.T + x @ delta.T


def sparse_delta_grads(x, w, idx, theta, g):
    """Oracle for the NeuroAda backward.

    g: [B, d_out] upstream cotangent.
    Returns (dx [B, d_in], dtheta [d_out, k]) — the only two gradients the
    method ever needs (w is frozen, idx is integer metadata).
    """
    delta = scatter_delta_dense(w.shape, idx, theta)
    dx = g @ (w + delta)
    # dtheta[i, j] = Σ_b g[b, i] · x[b, idx[i, j]]
    dtheta = jnp.einsum("bi,bij->ij", g, x[:, idx])
    return dx, dtheta.astype(theta.dtype)


def topk_rows(w, k):
    """Oracle for neuron-wise top-k |w| selection.

    Returns idx [d_out, k] int32: per row, the indices of the k
    largest-magnitude entries, ordered by descending |w| with ties broken by
    the lower index (jax.lax.top_k semantics, which we adopt as the spec).
    """
    _, idx = jax.lax.top_k(jnp.abs(w), k)
    return idx.astype(jnp.int32)


def merge(w, idx, theta):
    """Oracle for the one-shot merge: W ← W + Δ (Algorithm 1, phase 3)."""
    return w + scatter_delta_dense(w.shape, idx, theta).astype(w.dtype)
