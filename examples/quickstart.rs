//! Quickstart: the NeuroAda pipeline in ~40 lines.
//!
//! 1. get a pretrained backbone (cached; pretrains on first run),
//! 2. Phase 1 — magnitude top-k selection (task-agnostic),
//! 3. Phase 2 — fine-tune only the bypass parameters through the AOT
//!    train-step artifact,
//! 4. Phase 3 — merge the deltas and evaluate (zero inference overhead).
//!
//! Run: `cargo run --release --example quickstart`
//! (requires `make artifacts` once; use QUICK=1 for smoke budgets)

use neuroada::coordinator::common::{Coordinator, RunOpts};
use neuroada::data::tasks;
use neuroada::peft::{MethodKind, Strategy};

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("QUICK").is_ok();
    let mut opts = if quick { RunOpts::smoke() } else { RunOpts::default() };
    opts.finetune_steps = if quick { 60 } else { 600 };
    let c = Coordinator::new("artifacts", opts)?;

    // a pretrained backbone for the smallest preset (cached under runs/)
    let backbone = c.backbone("nano")?;

    // fine-tune with NeuroAda: top-1 input connection per neuron
    let task = tasks::by_name("cs-boolq").unwrap();
    let result = c.run_one(
        "nano",
        &backbone,
        MethodKind::NeuroAda { k: 1 },
        Strategy::Magnitude,
        1.0, // all neurons participate (the paper's core design goal)
        &task,
        None,
        None,
    )?;

    println!(
        "NeuroAda(top-1) on {}: accuracy {:.3} (zero-shot {:.3}) with {:.4}% \
         trainable params ({} bypasses), {:.1} samples/s",
        task.name,
        result.metric,
        result.zero_shot,
        result.params_percent,
        result.trainable_params,
        result.samples_per_sec,
    );
    Ok(())
}
