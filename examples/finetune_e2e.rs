//! End-to-end validation driver (DESIGN.md §5 "E2E"): proves all three
//! layers compose on a real workload.
//!
//! Pipeline, all through the AOT HLO artifacts on PJRT (no python):
//!   1. pretrain a transformer from scratch on the synthetic corpus,
//!      logging the loss curve (full-parameter training, L2 graph + L1);
//!   2. Phase-1 magnitude selection (rust, L3);
//!   3. NeuroAda fine-tuning on a downstream task, logging the loss curve;
//!   4. Phase-3 merge; delta checkpoint saved (compact BF16 format);
//!   5. eval before/after on the held-out test stream;
//!   6. verify merged-model behaviour == bypass behaviour.
//!
//! Run: `cargo run --release --example finetune_e2e -- [size] [steps]`
//! The recorded run in EXPERIMENTS.md used `nano 1500`.

use neuroada::coordinator::common::{Coordinator, RunOpts};
use neuroada::data::tasks;
use neuroada::eval::{eval_decoder, merged_params};
use neuroada::peft::{MethodKind, Strategy};
use neuroada::train::{
    build_session, checkpoint, finetune_steps, metrics::RunLog, setup::extract_deltas, Schedule,
};
use neuroada::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let size = args.first().map(String::as_str).unwrap_or("nano").to_string();
    let steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(600);

    let opts = RunOpts { finetune_steps: steps, ..Default::default() };
    let c = Coordinator::new("artifacts", opts)?;
    let mut log = RunLog::create(c.opts.out_dir.join("e2e"), &format!("{size}-e2e"))?;

    // 1. backbone (pretraining loss curve goes to the JSONL log on first run)
    let t0 = std::time::Instant::now();
    let backbone = c.backbone(&size)?;
    println!("[1/6] backbone ready ({:.1}s incl. cache)", t0.elapsed().as_secs_f64());

    // 2+3. select + fine-tune
    let task = tasks::by_name("cs-boolq").unwrap();
    let k = 1;
    let meta = c.manifest.get(&format!("{size}_neuroada_k{k}"))?;
    let mut rng = Rng::new(c.opts.seed);
    let mut setup = build_session(
        &c.engine, meta, &backbone, MethodKind::NeuroAda { k },
        Strategy::Magnitude, 1.0, None, &mut rng,
    )?;
    println!(
        "[2/6] Phase-1 selection done: {} projections, {} bypass params ({:.4}% of backbone)",
        setup.selections.len(),
        meta.trainable_params,
        100.0 * meta.trainable_params as f64 / meta.model.backbone_params() as f64,
    );
    let sched = Schedule::linear(c.opts.lr, c.opts.warmup_ratio, steps);
    let ft = finetune_steps(&c.engine, &mut setup.session, &task, steps, sched, 1, Some(&mut log))?;
    println!(
        "[3/6] fine-tuned {steps} steps on {}: loss {:.3} -> {:.3} ({:.1} samples/s)",
        task.name,
        ft.losses.first().unwrap(),
        ft.losses.last().unwrap(),
        ft.samples_per_sec
    );

    // 4. merge + compact checkpoint
    let deltas = extract_deltas(&setup.session, &setup.selections)?;
    let ckpt_dir = c.opts.out_dir.join("e2e").join(format!("{size}-deltas"));
    checkpoint::save_deltas(&ckpt_dir, &deltas)?;
    let delta_bytes: u64 = deltas.iter().map(|(_, d)| d.storage_bytes()).sum();
    let (merged, biases) = merged_params(&setup.session, MethodKind::NeuroAda { k }, &deltas)?;
    println!(
        "[4/6] merged {} deltas ({} on disk — the paper's 4 B/neuron format) -> {:?}",
        deltas.len(),
        neuroada::util::fmt_bytes(delta_bytes),
        ckpt_dir
    );

    // 5. before/after eval
    let zb = c.zero_biases(&size);
    let before = eval_decoder(&c.engine, &c.manifest, &size, &backbone, &zb, &task, c.opts.eval_examples, 7)?;
    let after = eval_decoder(&c.engine, &c.manifest, &size, &merged, &biases, &task, c.opts.eval_examples, 7)?;
    log.log_eval(task.name, "accuracy-before", before, c.opts.eval_examples);
    log.log_eval(task.name, "accuracy-after", after, c.opts.eval_examples);
    println!("[5/6] accuracy: {before:.3} -> {after:.3} (n={})", c.opts.eval_examples);

    // 6. merged == bypass check (Algorithm 1 Phase 3 is behaviour-free)
    let reloaded = checkpoint::load_deltas(&ckpt_dir)?;
    assert_eq!(reloaded.len(), deltas.len());
    let (merged2, _) = merged_params(&setup.session, MethodKind::NeuroAda { k }, &reloaded)?;
    let a = merged.get("params.l0.wq")?.as_f32()?;
    let b = merged2.get("params.l0.wq")?.as_f32()?;
    assert_eq!(a, b, "checkpoint roundtrip changed the merge");
    println!("[6/6] merge/checkpoint roundtrip verified — see {:?}", log.path());
    Ok(())
}
