//! Memory audit: the paper's memory claims (Table 1, Eq. 5/6, Figure 5's
//! memory axis) as an executable report — analytic model at the paper's
//! dtypes (BF16 weights/grads + FP32 moments) side by side with the bytes a
//! real training session holds on this substrate (f32).
//!
//! Run: `cargo run --release --example memory_audit`

use neuroada::config::presets;
use neuroada::model::init::init_params;
use neuroada::peft::memory::DtypeModel;
use neuroada::peft::{Method, MethodKind, Strategy};
use neuroada::runtime::{Engine, Manifest};
use neuroada::train::build_session;
use neuroada::util::rng::Rng;
use neuroada::util::table::Table;
use neuroada::util::{fmt_bytes, fmt_ratio};

fn main() -> anyhow::Result<()> {
    // Table 1 (pure arithmetic — LLaMA-scale projections)
    let mut t1 = Table::new("Table 1 — per-projection sparsity-pattern memory (k=1)")
        .header(&["Model", "d_model", "Mask (1 bit/w)", "NeuroAda", "Saving"]);
    for r in neuroada::peft::memory::table1() {
        t1.row(r.render_cells());
    }
    t1.print();

    // Eq. 5/6 at LLaMA-2-13B scale
    let d = 5120u64;
    println!(
        "\nEq. 5/6 at d_in = {d}, k = 1: AdamW state {} -> {} per projection ({} reduction)\n",
        fmt_bytes(2 * d * d * 4),
        fmt_bytes(2 * d * 4),
        fmt_ratio(neuroada::peft::optimizer::state_reduction(d as usize, 1)),
    );

    // Analytic vs measured on the real artifacts (all presets with a
    // lowered masked artifact)
    let manifest = Manifest::load("artifacts")?;
    let engine = Engine::shared();
    let mut t = Table::new("Adaptation overhead — analytic (bf16 paper dtypes) vs measured (f32 session)")
        .header(&["Model", "Method", "Analytic overhead", "Measured state+aux", "Masked/NeuroAda"]);
    for size in ["nano", "micro", "small", "base"] {
        let cfg = presets::model(size).unwrap();
        let mut rng = Rng::new(1);
        let params = init_params(&cfg, &mut rng);
        let mut na_measured = 0u64;
        for method in [MethodKind::NeuroAda { k: 1 }, MethodKind::Masked { k: 1 }] {
            let artifact = format!("{size}_{}", method.artifact_fragment());
            let Ok(meta) = manifest.get(&artifact) else { continue };
            let setup = build_session(
                &engine, meta, &params, method, Strategy::Magnitude, 1.0, None, &mut rng,
            )?;
            let analytic = Method::new(method, cfg.projections(), cfg.backbone_params())
                .memory(DtypeModel::BF16);
            // measured: mutable state + selection metadata (aux.*)
            let measured = setup.session.state_bytes()
                + setup.session.store.bytes_under("aux.");
            let ratio = if matches!(method, MethodKind::NeuroAda { .. }) {
                na_measured = measured;
                String::new()
            } else {
                fmt_ratio(measured as f64 / na_measured.max(1) as f64)
            };
            t.row(vec![
                size.into(),
                method.name(),
                fmt_bytes(analytic.adaptation_overhead()),
                fmt_bytes(measured),
                ratio,
            ]);
            engine.evict(&artifact);
        }
        t.hline();
    }
    t.print();
    println!("\n(The measured masked/NeuroAda ratio is the paper's Figure 5 memory gap;\n it grows with d_model exactly as Eq. 5/6 predicts.)");
    Ok(())
}
