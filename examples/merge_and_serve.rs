//! Serving path: load a trained delta checkpoint, merge it into the
//! backbone (Algorithm 1 Phase 3 — zero inference overhead), and serve
//! batched multiple-choice requests through the eval artifact, reporting
//! latency and throughput.
//!
//! Run after `finetune_e2e` has produced a checkpoint:
//!   `cargo run --release --example merge_and_serve -- [size]`

use neuroada::config::presets;
use neuroada::coordinator::common::{Coordinator, RunOpts};
use neuroada::data::{eval_batch, tasks, Split};
use neuroada::runtime::{state::run_once, Value};
use neuroada::train::checkpoint;
use neuroada::util::stats::Summary;

fn main() -> anyhow::Result<()> {
    let size = std::env::args().nth(1).unwrap_or_else(|| "nano".into());
    let c = Coordinator::new("artifacts", RunOpts::default())?;
    let cfg = presets::model(&size).unwrap();

    // backbone + trained deltas (falls back to zero deltas if no checkpoint)
    let mut params = c.backbone(&size)?;
    let ckpt = c.opts.out_dir.join("e2e").join(format!("{size}-deltas"));
    match checkpoint::load_deltas(&ckpt) {
        Ok(deltas) => {
            let bytes: u64 = deltas.iter().map(|(_, d)| d.storage_bytes()).sum();
            neuroada::model::merge_deltas(&mut params, &deltas)?;
            println!("merged {} deltas ({}) from {ckpt:?}", deltas.len(), neuroada::util::fmt_bytes(bytes));
        }
        Err(_) => println!("no checkpoint at {ckpt:?} — serving the raw backbone (run finetune_e2e first)"),
    }

    // serve batched requests
    let task = tasks::by_name("cs-boolq").unwrap();
    let meta = c.manifest.get(&format!("{size}_eval"))?;
    let mut store = params.clone();
    for (name, d_out, _) in cfg.proj_shapes() {
        store.insert_f32(format!("biases.{name}"), &[d_out], vec![0.0; d_out]);
    }
    let n_batches = 24;
    let mut lat = Vec::new();
    let mut correct = 0usize;
    let mut total = 0usize;
    for i in 0..n_batches {
        let examples = neuroada::data::example_stream(&task, Split::Test, 1000 + i, cfg.vocab, cfg.seq - 2, cfg.batch);
        let eb = eval_batch(&examples, cfg.seq);
        let t0 = std::time::Instant::now();
        store.insert("tokens", Value::I32 { shape: vec![cfg.batch, cfg.seq], data: eb.tokens });
        store.insert("pad_mask", Value::F32 { shape: vec![cfg.batch, cfg.seq], data: eb.pad_mask });
        store.insert("last_pos", Value::I32 { shape: vec![cfg.batch], data: eb.last_pos });
        let out = run_once(&c.engine, meta, &store)?;
        lat.push(t0.elapsed().as_secs_f64());
        let logits = out.get(&meta.outputs[0].name)?.as_f32()?;
        for (j, ex) in examples.iter().enumerate() {
            let row = &logits[j * cfg.vocab..(j + 1) * cfg.vocab];
            let pick = ex.options.iter().enumerate()
                .max_by(|a, b| row[*a.1 as usize].partial_cmp(&row[*b.1 as usize]).unwrap())
                .map(|(x, _)| x).unwrap();
            if pick == ex.label {
                correct += 1;
            }
            total += 1;
        }
    }
    let s = Summary::of(&lat);
    println!(
        "served {n_batches} batches × {}: accuracy {:.3}, p50 {:.1} ms, p95 {:.1} ms, {:.0} req/s",
        cfg.batch,
        correct as f64 / total as f64,
        s.p50 * 1e3,
        s.p95 * 1e3,
        cfg.batch as f64 / s.mean,
    );
    println!("(merged model = plain dense network: the serving path has no NeuroAda machinery at all)");
    Ok(())
}
