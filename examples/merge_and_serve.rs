//! Serving path, now on the `serve` subsystem: register trained NeuroAda
//! delta checkpoints as named adapters on one frozen backbone, then serve a
//! batched multiple-choice request stream through the production scheduler
//! (continuous micro-batching, merged-LRU + sparse-bypass paths), reporting
//! accuracy, latency percentiles and throughput.
//!
//! The example and `neuroada serve` share one code path — `serve::Server` —
//! so what this demonstrates is exactly what production runs.
//!
//! Run after `finetune_e2e` has produced a checkpoint (falls back to a
//! synthetic adapter otherwise):
//!   `cargo run --release --example merge_and_serve -- [size]`

use neuroada::bench::serve_bench::synth_adapter;
use neuroada::config::presets;
use neuroada::coordinator::common::RunOpts;
use neuroada::data::{tasks, Split};
use neuroada::serve::{
    backend_from_manifest, load_or_init_backbone, AdapterRegistry, RegistryCfg, Request,
    ServeCfg, Server,
};
use neuroada::train::checkpoint;
use neuroada::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let size = std::env::args().nth(1).unwrap_or_else(|| "nano".into());
    let cfg = presets::model(&size).ok_or_else(|| anyhow::anyhow!("unknown size {size:?}"))?;
    let opts = RunOpts::default();
    let backbone = load_or_init_backbone(&opts, &cfg)?;

    // adapters: the finetune_e2e checkpoint, plus a synthetic second tenant
    // to show two adapters sharing the resident backbone
    let registry = AdapterRegistry::new(
        cfg.clone(),
        backbone.clone(),
        RegistryCfg { merged_capacity: 1, promote_after: 2 },
    );
    let ckpt = opts.out_dir.join("e2e").join(format!("{size}-deltas"));
    match checkpoint::load_deltas(&ckpt) {
        Ok(deltas) => {
            let bytes: u64 = deltas.iter().map(|(_, d)| d.storage_bytes()).sum();
            registry.register("e2e", deltas)?;
            println!("registered adapter \"e2e\" ({}) from {ckpt:?}", neuroada::util::fmt_bytes(bytes));
        }
        Err(_) => {
            registry.register("e2e", synth_adapter(&cfg, &backbone, 1, 0xE2E)?)?;
            println!("no checkpoint at {ckpt:?} — registered a synthetic \"e2e\" adapter");
        }
    }
    registry.register("tenant-b", synth_adapter(&cfg, &backbone, 1, 0xB)?)?;

    // backend: HLO eval artifact when available, else pure-rust forward
    let backend = backend_from_manifest("artifacts", &size);

    let srv = Server::start(registry, ServeCfg { max_batch: cfg.batch, ..Default::default() }, backend)?;

    // serve the held-out stream of the boolq-like task, submitted in bursts
    // so continuous micro-batching has same-adapter requests to coalesce
    let task = tasks::by_name("cs-boolq").unwrap();
    let n_req = 24 * cfg.batch;
    let examples = neuroada::data::example_stream(&task, Split::Test, 1000, cfg.vocab, cfg.seq - 2, n_req);
    let mut rng = Rng::new(1000);
    let mut correct = 0usize;
    let mut total = 0usize;
    for chunk in examples.chunks(cfg.batch) {
        let submitted: Vec<_> = chunk
            .iter()
            .map(|ex| {
                // 1-in-8 requests hit the second tenant: same backbone, other deltas
                let adapter = if rng.below(8) == 0 { "tenant-b" } else { "e2e" };
                let ticket = srv.submit(Request {
                    adapter: adapter.into(),
                    prompt: ex.prompt.clone(),
                    options: ex.options.clone(),
                });
                (adapter, ticket)
            })
            .collect();
        for ((adapter, ticket), ex) in submitted.into_iter().zip(chunk) {
            let resp = ticket
                .map_err(|e| anyhow::anyhow!("submit: {e}"))?
                .wait()
                .map_err(|e| anyhow::anyhow!("serve: {e}"))?;
            if adapter == "e2e" {
                total += 1;
                if resp.pick == ex.label {
                    correct += 1;
                }
            }
        }
    }
    let report = srv.shutdown();
    let (p50, p95) = report
        .latency
        .as_ref()
        .map(|s| (s.p50 * 1e3, s.p95 * 1e3))
        .unwrap_or((f64::NAN, f64::NAN));
    println!(
        "served {} requests: e2e accuracy {:.3}, p50 {p50:.1} ms, p95 {p95:.1} ms, {:.0} req/s, mean batch {:.2}",
        report.served,
        correct as f64 / total.max(1) as f64,
        report.req_per_sec,
        report.mean_batch,
    );
    for (name, c) in &report.adapters {
        println!(
            "  {name}: {} served, {} merged hits / {} bypass hits",
            c.served, c.merged_hits, c.bypass_hits
        );
    }
    println!("(one frozen backbone, N adapters: hot ones merged+cached, cold ones served via the sparse bypass)");
    Ok(())
}
